//! Design-space exploration engine.
//!
//! Generates design-point grids ([`sweep`]), evaluates them through either
//! the native Rust model (threaded) or the AOT-compiled PJRT artifact
//! ([`Evaluator`]), extracts Pareto fronts ([`pareto`]), and regenerates
//! the paper's figures ([`figures`]).
//!
//! ## The sweep hot path
//!
//! Three drivers, fastest first (all bit-identical — see
//! `tests/sweep_stream_properties.rs`):
//!
//! * [`run_sweep_fold`] — streaming rollup over the grid through the
//!   invariant-hoisted [`PreparedModel`] kernel: per-(ENOB, tech) row
//!   constants and the per-(throughput, n_adcs) `log10` table are
//!   computed once, queries are generated per chunk by odometer, and
//!   nothing sweep-sized is ever materialized. Use for Pareto/min-EAP
//!   style summaries of grids with millions of points.
//! * [`run_sweep_prepared`] — same kernel, materialized
//!   `Vec<EvaluatedPoint>` output (filled in place by the pool).
//! * [`run_sweep`] — the general path over any [`Evaluator`] (native or
//!   PJRT), generating queries chunk-by-chunk instead of up front.
//!
//! Beyond one process, [`shard`] plans disjoint index sub-ranges over a
//! spec ([`ShardPlan`]), runs each to a self-describing JSON artifact
//! ([`ShardArtifact`]), and merges any subset back ([`merge_shards`])
//! bit-identically to the single-process streaming rollups.
//!
//! ## Numeric tiers
//!
//! Each driver also comes in a `_tier` form taking a [`SweepTier`].
//! [`SweepTier::Exact`] (what the plain names run) is the bit-exact
//! libm-backed reference above; [`SweepTier::Fast`] evaluates four grid
//! points per iteration through [`PreparedRowLanes`] and the
//! `util::fastmath` polynomial `pow10` — ULP-bounded against the exact
//! tier (`tests/simd_equivalence.rs`), never used by fingerprinted or
//! golden-pinned outputs ([`shard`] calls only the exact-tier entry
//! points, and the `determinism` lint enforces that). Fast-tier results
//! do not depend on worker count, chunking, or SIMD backend: the quad
//! and tail kernels are bit-identical to each other on every host.

pub mod accel;
pub mod figures;
pub mod pareto;
pub mod shard;
pub mod snr;
pub mod sweep;

pub use accel::{AccelPoint, AccelSweepSpec, run_accel_sweep};
pub use pareto::{FrontK, StreamingFront, pareto_front, pareto_front_k};
pub use shard::{
    MergedSweep, ShardArtifact, ShardPlan, ShardSelector, SweepSummary,
    artifact_file_name as shard_artifact_file_name, merge_shards, model_fingerprint,
    sweep_fingerprint, sweep_fingerprint_with,
};
pub use snr::{SnrContext, compute_snr_db};
pub use sweep::{SweepSpec, SweepTier};

use crate::adc::{AdcMetrics, AdcModel, AdcQuery, PreparedModel, PreparedRow, PreparedRowLanes};
use crate::error::{Error, Result};
use crate::exec::{CancelToken, Pool};
use crate::runtime::AdcModelEngine;
use crate::util::logspace::log10;

/// Queries generated per chunk by the streaming sweep drivers: large
/// enough to amortize dispatch, small enough that a chunk's queries and
/// metrics stay cache-resident instead of sweep-sized.
const SWEEP_CHUNK: usize = 16 * 1024;

/// A design-point evaluator: queries in, ADC metrics out.
pub trait Evaluator {
    /// Evaluate a batch of queries.
    fn eval(&self, queries: &[AdcQuery]) -> Result<Vec<AdcMetrics>>;

    /// Human-readable backend name.
    fn backend_name(&self) -> &'static str;

    /// Preferred batch-size multiple for [`run_sweep`]'s chunking, if the
    /// backend pads partial batches (the PJRT artifact does: every chunk
    /// not a multiple of its compiled batch wastes device work on pad
    /// rows). `None` means any chunk size is fine.
    fn batch_hint(&self) -> Option<usize> {
        None
    }
}

/// Native Rust evaluation, threaded across the shared [`Pool::global`].
pub struct NativeEvaluator {
    /// The model to evaluate.
    pub model: AdcModel,
    /// `1` = serial on the calling thread; anything else routes through
    /// the shared pool (its fixed width governs actual parallelism).
    pub workers: usize,
    /// Chunk size per work item (amortizes claim overhead).
    pub chunk: usize,
    /// Numeric tier: [`SweepTier::Fast`] routes batches through the
    /// lane-batched fast kernel instead of [`AdcModel::eval`]. Results
    /// are then ULP-bounded, not bit-exact — see the module docs.
    pub tier: SweepTier,
}

impl NativeEvaluator {
    /// Evaluator with sensible defaults. The 1024-point chunk keeps
    /// claims ~100 µs of work — big enough to amortize a deque pop, small
    /// enough that even a fig-sized sweep fans out across the pool.
    pub fn new(model: AdcModel) -> Self {
        NativeEvaluator {
            model,
            workers: crate::exec::default_workers(),
            chunk: 1024,
            tier: SweepTier::Exact,
        }
    }

    /// Serial evaluator (useful for micro-benchmarks).
    pub fn serial(model: AdcModel) -> Self {
        NativeEvaluator { model, workers: 1, chunk: usize::MAX, tier: SweepTier::Exact }
    }

    /// Builder-style tier switch.
    pub fn with_tier(mut self, tier: SweepTier) -> Self {
        self.tier = tier;
        self
    }

    /// Fast-tier batch evaluation: whole quads through
    /// [`PreparedRowLanes::eval4`], remainders through the scalar fast
    /// kernel. The two are bit-identical, so results do not depend on
    /// worker count, chunk boundaries, or SIMD backend.
    fn eval_fast(&self, queries: &[AdcQuery]) -> Vec<AdcMetrics> {
        let prepared = PreparedModel::new(&self.model);
        let eval_range = |start: usize, out: &mut [AdcMetrics]| {
            let mut l = 0usize;
            while l + 4 <= out.len() {
                let q = &queries[start + l..start + l + 4];
                let rows = [
                    prepared.row(q[0].enob, q[0].tech_nm),
                    prepared.row(q[1].enob, q[1].tech_nm),
                    prepared.row(q[2].enob, q[2].tech_nm),
                    prepared.row(q[3].enob, q[3].tech_nm),
                ];
                let lanes = PreparedRowLanes::gather([&rows[0], &rows[1], &rows[2], &rows[3]]);
                let log_f = [
                    log10(q[0].throughput_per_adc()),
                    log10(q[1].throughput_per_adc()),
                    log10(q[2].throughput_per_adc()),
                    log10(q[3].throughput_per_adc()),
                ];
                let totals = [
                    q[0].total_throughput,
                    q[1].total_throughput,
                    q[2].total_throughput,
                    q[3].total_throughput,
                ];
                let ns = [q[0].n_adcs, q[1].n_adcs, q[2].n_adcs, q[3].n_adcs];
                out[l..l + 4].copy_from_slice(&lanes.eval4(log_f, totals, ns));
                l += 4;
            }
            for j in l..out.len() {
                let q = &queries[start + j];
                out[j] = prepared.row(q.enob, q.tech_nm).eval_log_f_fast(
                    log10(q.throughput_per_adc()),
                    q.total_throughput,
                    q.n_adcs,
                );
            }
        };
        let mut out = vec![AdcMetrics::default(); queries.len()];
        if self.workers == 1 || queries.len() <= 1 {
            eval_range(0, &mut out);
        } else {
            Pool::global()
                .fill_chunk_ranges(&mut out, self.chunk, |start, slice| eval_range(start, slice));
        }
        out
    }
}

impl Evaluator for NativeEvaluator {
    fn eval(&self, queries: &[AdcQuery]) -> Result<Vec<AdcMetrics>> {
        if self.tier == SweepTier::Fast {
            return Ok(self.eval_fast(queries));
        }
        if self.workers == 1 || queries.len() <= 1 {
            return Ok(queries.iter().map(|q| self.model.eval(q)).collect());
        }
        // Zero-copy result path: workers overwrite disjoint chunk slices
        // of the pre-sized output in place (no lock, no stitch).
        let mut out = vec![AdcMetrics::default(); queries.len()];
        Pool::global().fill_chunk_ranges(&mut out, self.chunk, |start, slice| {
            for (i, slot) in slice.iter_mut().enumerate() {
                *slot = self.model.eval(&queries[start + i]);
            }
        });
        Ok(out)
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }
}

/// PJRT evaluation through the compiled `adc_model.hlo.txt` artifact.
///
/// Tuned models ride through via [`AdcModel::folded_coefficients`]. The
/// PJRT client is single-threaded here; batching (the artifact's 4096
/// design points per execute) is what amortizes dispatch.
pub struct PjrtEvaluator {
    engine: AdcModelEngine,
    model: AdcModel,
}

impl PjrtEvaluator {
    /// Wrap a compiled engine and the model whose coefficients to use.
    pub fn new(engine: AdcModelEngine, model: AdcModel) -> Self {
        PjrtEvaluator { engine, model }
    }
}

impl Evaluator for PjrtEvaluator {
    fn eval(&self, queries: &[AdcQuery]) -> Result<Vec<AdcMetrics>> {
        self.engine.eval(queries, &self.model.folded_coefficients())
    }

    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn batch_hint(&self) -> Option<usize> {
        Some(self.engine.batch_size())
    }
}

/// One evaluated design point.
///
/// `Default` is an all-zero placeholder for in-place buffer fills, never
/// a meaningful result.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvaluatedPoint {
    /// The query.
    pub query: AdcQuery,
    /// The model's outputs.
    pub metrics: AdcMetrics,
}

/// Evaluate a whole sweep, generating queries chunk-by-chunk (the full
/// query vector is never materialized; the evaluated output of course is).
pub fn run_sweep(spec: &SweepSpec, evaluator: &dyn Evaluator) -> Result<Vec<EvaluatedPoint>> {
    let n = spec.checked_len().ok_or_else(|| {
        Error::Numeric(
            "sweep grid length overflows usize; split the spec into sub-range specs".into(),
        )
    })?;
    // Round the chunk up to a whole multiple of the backend's batch so a
    // padding evaluator (PJRT) pads at most once per chunk tail instead
    // of on every chunk.
    let chunk = match evaluator.batch_hint() {
        Some(batch) if batch > 0 => SWEEP_CHUNK.div_ceil(batch) * batch,
        _ => SWEEP_CHUNK,
    };
    let mut out = Vec::with_capacity(n);
    let mut buf: Vec<AdcQuery> = Vec::with_capacity(chunk.min(n));
    let mut start = 0usize;
    while start < n {
        let end = (start + chunk).min(n);
        buf.clear();
        spec.fill_range(start..end, &mut buf);
        let metrics = evaluator.eval(&buf)?;
        out.extend(
            buf.iter()
                .zip(metrics)
                .map(|(&query, metrics)| EvaluatedPoint { query, metrics }),
        );
        start = end;
    }
    Ok(out)
}

/// Per-sweep caches for the invariant-hoisted kernel: one [`PreparedRow`]
/// per (ENOB, tech) pair and one `log10(total/n)` entry per
/// (throughput, n_adcs) pair. The inner loop does table lookups and
/// multiply-adds plus the two `pow10` calls — no `log10`, no division.
struct PreparedSweep<'a> {
    spec: &'a SweepSpec,
    /// `rows[ei * tech_nms.len() + ki]`.
    rows: Vec<PreparedRow>,
    /// `log_f[ti * n_adcs.len() + ni]` (bit-exact vs `AdcModel::eval`).
    log_f: Vec<f64>,
}

impl<'a> PreparedSweep<'a> {
    fn new(spec: &'a SweepSpec, model: &AdcModel) -> PreparedSweep<'a> {
        let prepared = PreparedModel::new(model);
        let mut rows = Vec::with_capacity(spec.enobs.len() * spec.tech_nms.len());
        for &enob in &spec.enobs {
            for &tech in &spec.tech_nms {
                rows.push(prepared.row(enob, tech));
            }
        }
        PreparedSweep { spec, rows, log_f: spec.log_per_adc_table() }
    }

    /// Apply `f(index, query, metrics)` to every point of a contiguous
    /// index range, in grid order (shared odometer iteration —
    /// [`SweepSpec::for_each_index_in_range`] — so this path cannot
    /// drift from query materialization).
    fn for_each_in_range<F: FnMut(usize, &AdcQuery, &AdcMetrics)>(
        &self,
        range: std::ops::Range<usize>,
        mut f: F,
    ) {
        let n = self.spec.n_adcs.len();
        let k = self.spec.tech_nms.len();
        self.spec.for_each_index_in_range(range, |i, ei, ti, ki, ni| {
            let query = AdcQuery {
                enob: self.spec.enobs[ei],
                total_throughput: self.spec.total_throughputs[ti],
                tech_nm: self.spec.tech_nms[ki],
                n_adcs: self.spec.n_adcs[ni],
            };
            let metrics = self.rows[ei * k + ki].eval_log_f(
                self.log_f[ti * n + ni],
                query.total_throughput,
                query.n_adcs,
            );
            f(i, &query, &metrics);
        });
    }

    /// Fast-tier variant of [`PreparedSweep::for_each_in_range`]: the
    /// same odometer iteration, buffered into quads for
    /// [`PreparedRowLanes::eval4`] (consecutive grid points usually sit
    /// on different rows — `n_adcs` varies fastest — hence the per-lane
    /// gather). Points are still handed to `f` in exact grid order;
    /// sub-quad remainders go through the scalar fast kernel, which is
    /// bit-identical to the lane kernel, so range splits cannot change
    /// results.
    fn for_each_in_range_fast<F: FnMut(usize, &AdcQuery, &AdcMetrics)>(
        &self,
        range: std::ops::Range<usize>,
        mut f: F,
    ) {
        let n = self.spec.n_adcs.len();
        let k = self.spec.tech_nms.len();
        let mut idx = [0usize; 4];
        let mut row_i = [0usize; 4];
        let mut log_fs = [0.0f64; 4];
        let mut queries = [AdcQuery::default(); 4];
        let mut filled = 0usize;
        self.spec.for_each_index_in_range(range, |i, ei, ti, ki, ni| {
            idx[filled] = i;
            row_i[filled] = ei * k + ki;
            log_fs[filled] = self.log_f[ti * n + ni];
            queries[filled] = AdcQuery {
                enob: self.spec.enobs[ei],
                total_throughput: self.spec.total_throughputs[ti],
                tech_nm: self.spec.tech_nms[ki],
                n_adcs: self.spec.n_adcs[ni],
            };
            filled += 1;
            if filled == 4 {
                filled = 0;
                let lanes = PreparedRowLanes::gather([
                    &self.rows[row_i[0]],
                    &self.rows[row_i[1]],
                    &self.rows[row_i[2]],
                    &self.rows[row_i[3]],
                ]);
                let totals = [
                    queries[0].total_throughput,
                    queries[1].total_throughput,
                    queries[2].total_throughput,
                    queries[3].total_throughput,
                ];
                let ns = [queries[0].n_adcs, queries[1].n_adcs, queries[2].n_adcs, queries[3].n_adcs];
                let metrics = lanes.eval4(log_fs, totals, ns);
                for l in 0..4 {
                    f(idx[l], &queries[l], &metrics[l]);
                }
            }
        });
        for l in 0..filled {
            let metrics = self.rows[row_i[l]].eval_log_f_fast(
                log_fs[l],
                queries[l].total_throughput,
                queries[l].n_adcs,
            );
            f(idx[l], &queries[l], &metrics);
        }
    }

    /// Tier dispatch over the two range drivers above.
    fn for_each_in_range_tier<F: FnMut(usize, &AdcQuery, &AdcMetrics)>(
        &self,
        tier: SweepTier,
        range: std::ops::Range<usize>,
        f: F,
    ) {
        match tier {
            SweepTier::Exact => self.for_each_in_range(range, f),
            SweepTier::Fast => self.for_each_in_range_fast(range, f),
        }
    }
}

/// Pool chunk size for streaming sweeps: enough chunks for stealing to
/// balance, large enough to amortize claims.
fn stream_chunk(n: usize) -> usize {
    (n / (crate::exec::default_workers() * 8)).clamp(1024, SWEEP_CHUNK).min(n.max(1))
}

/// Evaluate a whole sweep through the invariant-hoisted kernel,
/// bit-identical to [`run_sweep`] over a [`NativeEvaluator`] but several
/// times faster per point (see `BENCH_sweep.json`). `workers = 1` runs
/// serially; otherwise the shared pool fills the output in place.
pub fn run_sweep_prepared(
    spec: &SweepSpec,
    model: &AdcModel,
    workers: usize,
) -> Result<Vec<EvaluatedPoint>> {
    run_sweep_prepared_tier(spec, model, workers, SweepTier::Exact)
}

/// [`run_sweep_prepared`] on an explicit [`SweepTier`].
/// [`SweepTier::Fast`] evaluates quads through [`PreparedRowLanes`];
/// its output is ULP-bounded (not bit-exact) against the exact tier but
/// independent of `workers` and SIMD backend.
pub fn run_sweep_prepared_tier(
    spec: &SweepSpec,
    model: &AdcModel,
    workers: usize,
    tier: SweepTier,
) -> Result<Vec<EvaluatedPoint>> {
    let n = spec.checked_len().ok_or_else(|| {
        Error::Numeric(
            "sweep grid length overflows usize; split the spec into sub-range specs".into(),
        )
    })?;
    let prepared = PreparedSweep::new(spec, model);
    if workers == 1 || n <= 1 {
        let mut out = Vec::with_capacity(n);
        prepared.for_each_in_range_tier(tier, 0..n, |_, q, m| {
            out.push(EvaluatedPoint { query: *q, metrics: *m });
        });
        return Ok(out);
    }
    let mut out = vec![EvaluatedPoint::default(); n];
    Pool::global().fill_chunk_ranges(&mut out, stream_chunk(n), |start, slice| {
        let mut j = 0usize;
        prepared.for_each_in_range_tier(tier, start..start + slice.len(), |_, q, m| {
            slice[j] = EvaluatedPoint { query: *q, metrics: *m };
            j += 1;
        });
    });
    Ok(out)
}

/// Streaming sweep rollup: evaluate every grid point through the
/// invariant-hoisted kernel and fold it into an accumulator without ever
/// holding a `Vec<EvaluatedPoint>` (or the query vector).
///
/// * `init` builds a fresh accumulator (one per worker).
/// * `fold(acc, index, query, metrics)` absorbs one design point.
/// * `merge` combines two accumulators.
///
/// With `workers = 1` points are folded serially in exact grid order.
/// Otherwise chunk claim order is non-deterministic (work stealing), so
/// `fold`/`merge` must be insensitive to encounter order — min/max,
/// counts, [`StreamingFront`], or argmin with index tie-breaks all
/// qualify and reproduce the materialized result exactly.
///
/// # Panics
///
/// Unlike [`run_sweep`]/[`run_sweep_prepared`] (which return `Err`),
/// this panics if the grid's axis product overflows `usize` — streaming
/// still indexes points with `usize`, so such a spec must be split into
/// sub-range specs first. Keeping the infallible return preserves the
/// natural `fold` shape for the ~always case of an indexable grid.
pub fn run_sweep_fold<A, I, F, M>(
    spec: &SweepSpec,
    model: &AdcModel,
    workers: usize,
    init: I,
    fold: F,
    merge: M,
) -> A
where
    A: Send,
    I: Fn() -> A + Sync,
    F: Fn(&mut A, usize, &AdcQuery, &AdcMetrics) + Sync,
    M: Fn(A, A) -> A,
{
    // Streaming still indexes points with usize, so an overflowed grid
    // cannot be folded either — split it into sub-range specs instead.
    let n = spec
        .checked_len()
        .expect("sweep grid length overflows usize; split the spec into sub-range specs");
    run_sweep_fold_range_tier(spec, model, workers, SweepTier::Exact, 0..n, init, fold, merge)
}

/// [`run_sweep_fold`] on an explicit [`SweepTier`] (see
/// [`run_sweep_prepared_tier`] for the fast tier's contract). Panics
/// like [`run_sweep_fold`] on a length-overflowed grid.
pub fn run_sweep_fold_tier<A, I, F, M>(
    spec: &SweepSpec,
    model: &AdcModel,
    workers: usize,
    tier: SweepTier,
    init: I,
    fold: F,
    merge: M,
) -> A
where
    A: Send,
    I: Fn() -> A + Sync,
    F: Fn(&mut A, usize, &AdcQuery, &AdcMetrics) + Sync,
    M: Fn(A, A) -> A,
{
    let n = spec
        .checked_len()
        .expect("sweep grid length overflows usize; split the spec into sub-range specs");
    run_sweep_fold_range_tier(spec, model, workers, tier, 0..n, init, fold, merge)
}

/// [`run_sweep_fold`] restricted to a contiguous sub-range of grid
/// indices — the building block a shard of a multi-process sweep runs
/// (see [`shard`]). Fold indices are *global* grid indices, so a rollup
/// with index tie-breaks (min-EAP, [`StreamingFront`]) merges across
/// shards exactly as it would in one process. Panics if the grid length
/// overflows `usize` or the range exceeds it (shard planning goes
/// through [`ShardPlan::new`], which reports both as typed errors first).
pub fn run_sweep_fold_range<A, I, F, M>(
    spec: &SweepSpec,
    model: &AdcModel,
    workers: usize,
    range: std::ops::Range<usize>,
    init: I,
    fold: F,
    merge: M,
) -> A
where
    A: Send,
    I: Fn() -> A + Sync,
    F: Fn(&mut A, usize, &AdcQuery, &AdcMetrics) + Sync,
    M: Fn(A, A) -> A,
{
    run_sweep_fold_range_tier(spec, model, workers, SweepTier::Exact, range, init, fold, merge)
}

/// [`run_sweep_fold_range`] on an explicit [`SweepTier`]. Delegates to
/// [`run_sweep_fold_range_ctl`] with no controls attached, which cannot
/// report cancellation — the unwrap is infallible by construction.
#[allow(clippy::too_many_arguments)]
pub fn run_sweep_fold_range_tier<A, I, F, M>(
    spec: &SweepSpec,
    model: &AdcModel,
    workers: usize,
    tier: SweepTier,
    range: std::ops::Range<usize>,
    init: I,
    fold: F,
    merge: M,
) -> A
where
    A: Send,
    I: Fn() -> A + Sync,
    F: Fn(&mut A, usize, &AdcQuery, &AdcMetrics) + Sync,
    M: Fn(A, A) -> A,
{
    run_sweep_fold_range_ctl(
        spec,
        model,
        workers,
        tier,
        range,
        FoldCtl::default(),
        init,
        fold,
        merge,
    )
    .expect("a fold without a cancel token cannot be cancelled")
}

/// Cooperative controls threaded through a streaming fold: an optional
/// cancellation token checked at chunk boundaries and an optional
/// progress observer called with the number of points just folded.
///
/// Both hooks fire at the fold's internal chunk granularity
/// ([`stream_chunk`], 1024–16384 points), so neither perturbs the
/// per-point fold sequence: an uncancelled controlled fold produces
/// bytes identical to an uncontrolled one. The progress observer runs on
/// pool worker threads (serially on the caller when `workers == 1`) and
/// must therefore be cheap and `Sync`.
#[derive(Clone, Copy, Default)]
pub struct FoldCtl<'a> {
    /// Checked before each chunk; a tripped token stops further chunks
    /// and makes the fold return `None`.
    pub cancel: Option<&'a CancelToken>,
    /// Called with each completed chunk's point count.
    pub progress: Option<&'a (dyn Fn(usize) + Sync)>,
    /// Serial-path chunk override: bounds cancel latency and progress
    /// cadence for `workers == 1` folds (`cimdse serve --progress-every`
    /// on small grids). `None` keeps [`stream_chunk`]. Chunk size never
    /// changes result bytes — the points fold into one accumulator in
    /// exact grid order at any split — and the parallel path ignores the
    /// hint so its pool chunking stays canonical.
    pub chunk: Option<usize>,
    /// Trace parent for per-chunk child spans: the serving core's
    /// request span, set only while the global tracer is recording.
    /// `None` (the default, and the only state untraced traffic sees)
    /// records nothing and touches no clock — result bytes never depend
    /// on this field either way.
    pub trace: Option<crate::obs::TraceCtx>,
}

/// [`run_sweep_fold_range_tier`] with cooperative cancellation and
/// progress reporting — the single implementation every fold driver
/// funnels through. Shard execution ([`shard::SweepSummary`]) calls the
/// exact-tier path only, so fingerprinted artifacts never touch the
/// fast kernel.
///
/// Returns `None` iff `ctl.cancel` was tripped before the fold finished:
/// in-flight chunks still complete (cancellation is cooperative), but no
/// further chunks start and the partial accumulators are discarded. A
/// completed fold returns `Some` with bytes identical to the
/// uncontrolled fold — the controls only gate *whether* chunks run,
/// never how points fold within them.
#[allow(clippy::too_many_arguments)]
pub fn run_sweep_fold_range_ctl<A, I, F, M>(
    spec: &SweepSpec,
    model: &AdcModel,
    workers: usize,
    tier: SweepTier,
    range: std::ops::Range<usize>,
    ctl: FoldCtl<'_>,
    init: I,
    fold: F,
    merge: M,
) -> Option<A>
where
    A: Send,
    I: Fn() -> A + Sync,
    F: Fn(&mut A, usize, &AdcQuery, &AdcMetrics) + Sync,
    M: Fn(A, A) -> A,
{
    let len = spec
        .checked_len()
        .expect("sweep grid length overflows usize; split the spec into sub-range specs");
    assert!(
        range.start <= range.end && range.end <= len,
        "shard range {range:?} out of bounds for {len} grid points"
    );
    let cancelled = || ctl.cancel.is_some_and(CancelToken::is_cancelled);
    let report = |points: usize| {
        if let Some(progress) = ctl.progress {
            progress(points);
        }
    };
    // Per-chunk child span under the serving core's request span. The
    // guard records on drop, so holding it across the chunk times the
    // fold work; a `None` parent returns `None` and costs nothing.
    let chunk_span = |points: usize| {
        ctl.trace.map(|parent| {
            let mut s = crate::obs::child_span("chunk", parent);
            s.attr("points", crate::config::Value::Number(points as f64));
            s
        })
    };
    if cancelled() {
        return None;
    }
    let n = range.len();
    let prepared = PreparedSweep::new(spec, model);
    if workers == 1 || n <= 1 {
        // Serial path: walk the same chunk boundaries the pool would use
        // so cancel latency and progress cadence match the parallel path.
        // Chunking a serial fold cannot change its bytes — the points
        // fold into one accumulator in exact grid order either way.
        let chunk = ctl.chunk.unwrap_or_else(|| stream_chunk(n)).max(1);
        let mut acc = init();
        let mut at = range.start;
        while at < range.end {
            if cancelled() {
                return None;
            }
            let stop = (at + chunk).min(range.end);
            let span = chunk_span(stop - at);
            prepared.for_each_in_range_tier(tier, at..stop, |i, q, m| fold(&mut acc, i, q, m));
            drop(span);
            report(stop - at);
            at = stop;
        }
        return Some(acc);
    }
    let base = range.start;
    let accs = Pool::global().fold_chunks(n, stream_chunk(n), &init, |acc, chunk| {
        // Cooperative skip: once the token trips, claimed chunks return
        // without folding, so the pool drains in one claim pass instead
        // of computing the rest of an abandoned sweep.
        if cancelled() {
            return;
        }
        let span = chunk_span(chunk.len());
        prepared.for_each_in_range_tier(tier, base + chunk.start..base + chunk.end, |i, q, m| {
            fold(acc, i, q, m)
        });
        drop(span);
        report(chunk.len());
    });
    if cancelled() {
        return None;
    }
    Some(accs.into_iter().reduce(&merge).unwrap_or_else(init))
}

/// The min-EAP candidate ordering shared by [`sweep_min_eap`] and the
/// shard summaries ([`shard::SweepSummary`]): EAP ascending with the grid
/// index as tie-break. `total_cmp` (not `<`) so even NaN EAPs — only
/// possible from NaN spec values — rank deterministically (last),
/// matching a materialized argmin with the same comparator regardless of
/// steal/merge order.
pub(crate) fn eap_candidate_better(a: (usize, f64), b: (usize, f64)) -> bool {
    a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)) == std::cmp::Ordering::Less
}

/// Streaming min-EAP summary: the grid point minimizing
/// `energy_pj_per_convert × total_area_um2` (ties broken toward the
/// lowest grid index, so the result is deterministic under stealing).
/// Returns `None` for an empty grid.
pub fn sweep_min_eap(
    spec: &SweepSpec,
    model: &AdcModel,
    workers: usize,
) -> Option<EvaluatedPoint> {
    sweep_min_eap_tier(spec, model, workers, SweepTier::Exact)
}

/// [`sweep_min_eap`] on an explicit [`SweepTier`]. The fast tier's
/// per-point ULP error can in principle flip an argmin between two
/// near-tied candidates; exact-tier summaries (shards, serve) are
/// unaffected because they never run on [`SweepTier::Fast`].
pub fn sweep_min_eap_tier(
    spec: &SweepSpec,
    model: &AdcModel,
    workers: usize,
    tier: SweepTier,
) -> Option<EvaluatedPoint> {
    type Best = Option<(usize, f64, EvaluatedPoint)>;
    let better = |a: &(usize, f64, EvaluatedPoint), b: &(usize, f64, EvaluatedPoint)| {
        eap_candidate_better((a.0, a.1), (b.0, b.1))
    };
    run_sweep_fold_tier(
        spec,
        model,
        workers,
        tier,
        || None,
        |best: &mut Best, i, q, m| {
            let eap = m.energy_pj_per_convert * m.total_area_um2;
            let cand = (i, eap, EvaluatedPoint { query: *q, metrics: *m });
            if best.as_ref().map_or(true, |cur| better(&cand, cur)) {
                *best = Some(cand);
            }
        },
        |a, b| match (a, b) {
            (Some(a), Some(b)) => Some(if better(&a, &b) { a } else { b }),
            (a, None) => a,
            (None, b) => b,
        },
    )
    .map(|(_, _, point)| point)
}

/// Streaming K-objective Pareto front over a sweep: each grid point is
/// mapped to a `[f64; K]` objective row by `objectives(index, query,
/// metrics)` (all objectives minimized — negate anything to maximize,
/// as the SNR objective does) and folded into a [`FrontK`], so a
/// million-point sweep's front costs front-sized memory. The result is
/// the same index set [`pareto_front_k`] would return on the
/// materialized rows, for any worker count — both sides drop non-finite
/// rows, so the equivalence holds even under NaN objectives.
pub fn sweep_front_k<const K: usize, O>(
    spec: &SweepSpec,
    model: &AdcModel,
    workers: usize,
    objectives: O,
) -> FrontK<K>
where
    O: Fn(usize, &AdcQuery, &AdcMetrics) -> [f64; K] + Sync,
{
    run_sweep_fold(
        spec,
        model,
        workers,
        FrontK::new,
        |front: &mut FrontK<K>, i, q, m| front.push(objectives(i, q, m), i),
        FrontK::merge,
    )
}

/// Streaming Pareto front over (total power, total area): the indices
/// [`pareto_front`] would return on the materialized sweep, computed with
/// front-sized memory. The equivalence holds for finite objectives (any
/// valid spec); the streaming engine drops non-finite points, where
/// `pareto_front`'s behavior is unspecified. Implemented as the K = 2
/// instantiation of [`sweep_front_k`]; [`FrontK::into_indices`] returns
/// the same index order [`StreamingFront`] (which still backs the shard
/// summaries' pinned payloads) and `pareto_front` use.
pub fn sweep_power_area_front(spec: &SweepSpec, model: &AdcModel, workers: usize) -> Vec<usize> {
    sweep_front_k(spec, model, workers, |_i, _q, m: &AdcMetrics| {
        [m.total_power_w, m.total_area_um2]
    })
    .into_indices()
}

/// Streaming tri-objective (energy per convert, total area, −compute-SNR)
/// Pareto front — the `--objectives energy,area,snr` sweep. SNR enters
/// negated so every objective minimizes: a front point is one no rival
/// beats on energy, area, *and* fidelity simultaneously. The SNR of a
/// grid point depends only on its ENOB plus the fixed [`SnrContext`]
/// (analog sum size, cell bits).
pub fn sweep_energy_area_snr_front(
    spec: &SweepSpec,
    model: &AdcModel,
    workers: usize,
    ctx: &SnrContext,
) -> FrontK<3> {
    sweep_front_k(spec, model, workers, |_i, q: &AdcQuery, m: &AdcMetrics| {
        [m.energy_pj_per_convert, m.total_area_um2, -ctx.compute_snr_db(q.enob)]
    })
}

/// The objective sets the sweep stack serves. The classic pair is the
/// hard-coded behavior every pre-existing artifact, golden figure, and
/// served byte was pinned on; the tri set adds the compute-SNR axis.
/// Kept a closed enum (rather than arbitrary name lists) so every layer
/// — CLI, protocol, shard artifacts — agrees on exactly which
/// combinations exist and what their payloads look like.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ObjectiveSet {
    /// `power,area` — the classic 2-objective front ([`StreamingFront`]
    /// inside [`SweepSummary`]); requesting it explicitly is
    /// byte-identical to not passing objectives at all.
    #[default]
    PowerArea,
    /// `energy,area,snr` — the tri-objective front
    /// ([`sweep_energy_area_snr_front`]), carried as the summary's
    /// optional `snr_front` payload alongside the classic front.
    EnergyAreaSnr,
}

impl ObjectiveSet {
    /// The stable lower-case names, in objective order.
    pub fn names(self) -> &'static [&'static str] {
        match self {
            ObjectiveSet::PowerArea => &["power", "area"],
            ObjectiveSet::EnergyAreaSnr => &["energy", "area", "snr"],
        }
    }

    /// Parse a comma-separated objective list (`"energy,area,snr"`).
    /// Typed error naming the supported sets on anything else — unknown
    /// names, reordered or partial combinations, empty input.
    pub fn parse_csv(s: &str) -> Result<ObjectiveSet> {
        let names: Vec<&str> = s.split(',').map(str::trim).collect();
        ObjectiveSet::parse_names(&names)
    }

    /// [`ObjectiveSet::parse_csv`] over pre-split names (the protocol's
    /// JSON array form).
    pub fn parse_names(names: &[&str]) -> Result<ObjectiveSet> {
        for set in [ObjectiveSet::PowerArea, ObjectiveSet::EnergyAreaSnr] {
            if names == set.names() {
                return Ok(set);
            }
        }
        Err(Error::Config(format!(
            "unsupported objective set `{}` (supported: `power,area` and `energy,area,snr`)",
            names.join(",")
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metric_bits(m: &AdcMetrics) -> [u64; 4] {
        m.to_bits()
    }

    fn small_spec() -> SweepSpec {
        SweepSpec {
            enobs: vec![4.0, 8.0, 12.0],
            total_throughputs: vec![1e6, 1e8, 1e10],
            tech_nms: vec![16.0, 32.0],
            n_adcs: vec![1, 4],
        }
    }

    #[test]
    fn native_parallel_matches_serial() {
        let model = AdcModel::default();
        let spec = small_spec();
        let serial = run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap();
        let par = run_sweep(&spec, &NativeEvaluator::new(model)).unwrap();
        assert_eq!(serial.len(), 3 * 3 * 2 * 2);
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.metrics, b.metrics);
        }
    }

    #[test]
    fn evaluated_points_preserve_query_order() {
        let spec = SweepSpec {
            enobs: vec![4.0, 8.0],
            total_throughputs: vec![1e8],
            tech_nms: vec![32.0],
            n_adcs: vec![1],
        };
        let out = run_sweep(&spec, &NativeEvaluator::serial(AdcModel::default())).unwrap();
        assert_eq!(out[0].query.enob, 4.0);
        assert_eq!(out[1].query.enob, 8.0);
    }

    #[test]
    fn prepared_sweep_is_bit_identical_to_eval_path() {
        let model = AdcModel::default();
        let spec = small_spec();
        let baseline = run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap();
        for workers in [1usize, 4] {
            let fast = run_sweep_prepared(&spec, &model, workers).unwrap();
            assert_eq!(fast.len(), baseline.len());
            for (a, b) in baseline.iter().zip(&fast) {
                assert_eq!(a.query, b.query);
                assert_eq!(metric_bits(&a.metrics), metric_bits(&b.metrics));
            }
        }
    }

    #[test]
    fn fold_visits_every_point_once_in_order_when_serial() {
        let model = AdcModel::default();
        let spec = small_spec();
        let all = run_sweep_prepared(&spec, &model, 1).unwrap();
        let indices = run_sweep_fold(
            &spec,
            &model,
            1,
            Vec::new,
            |acc: &mut Vec<usize>, i, q, m| {
                // Serial fold sees the exact materialized values.
                assert_eq!(all[i].query, *q);
                assert_eq!(metric_bits(&all[i].metrics), metric_bits(m));
                acc.push(i);
            },
            |mut a, b| {
                a.extend(b);
                a
            },
        );
        assert_eq!(indices, (0..spec.len()).collect::<Vec<_>>());
    }

    #[test]
    fn fold_parallel_covers_every_point() {
        let model = AdcModel::default();
        let spec = SweepSpec::dense(6);
        let count = run_sweep_fold(
            &spec,
            &model,
            4,
            || 0usize,
            |acc, _, _, _| *acc += 1,
            |a, b| a + b,
        );
        assert_eq!(count, spec.len());
    }

    #[test]
    fn min_eap_matches_materialized_argmin() {
        let model = AdcModel::default();
        let spec = SweepSpec::dense(6);
        let all = run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap();
        let brute = all
            .iter()
            .enumerate()
            .min_by(|(i, a), (j, b)| {
                let ea = a.metrics.energy_pj_per_convert * a.metrics.total_area_um2;
                let eb = b.metrics.energy_pj_per_convert * b.metrics.total_area_um2;
                ea.total_cmp(&eb).then(i.cmp(j))
            })
            .unwrap()
            .1;
        for workers in [1usize, 4] {
            let streamed = sweep_min_eap(&spec, &model, workers).unwrap();
            assert_eq!(streamed.query, brute.query, "workers={workers}");
            assert_eq!(metric_bits(&streamed.metrics), metric_bits(&brute.metrics));
        }
    }

    #[test]
    fn streaming_front_matches_materialized_front() {
        let model = AdcModel::default();
        let spec = SweepSpec::dense(5);
        let all = run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap();
        let objectives: Vec<(f64, f64)> = all
            .iter()
            .map(|p| (p.metrics.total_power_w, p.metrics.total_area_um2))
            .collect();
        let brute = pareto_front(&objectives);
        for workers in [1usize, 4] {
            assert_eq!(
                sweep_power_area_front(&spec, &model, workers),
                brute,
                "workers={workers}"
            );
        }
    }

    #[test]
    fn tri_objective_front_matches_materialized_front() {
        let model = AdcModel::default();
        let spec = SweepSpec::dense(5);
        let ctx = snr::SnrContext::default();
        let all = run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap();
        let rows: Vec<[f64; 3]> = all
            .iter()
            .map(|p| {
                [
                    p.metrics.energy_pj_per_convert,
                    p.metrics.total_area_um2,
                    -ctx.compute_snr_db(p.query.enob),
                ]
            })
            .collect();
        let brute = pareto_front_k(&rows);
        assert!(!brute.is_empty());
        for workers in [1usize, 4] {
            let front = sweep_energy_area_snr_front(&spec, &model, workers, &ctx);
            assert_eq!(front.indices(), brute, "workers={workers}");
        }
        // The tri front is a genuine third axis: restricted to its first
        // two objectives it is at least as large as the 2-objective
        // front of those axes (SNR can only admit more points).
        let two: Vec<[f64; 2]> = rows.iter().map(|r| [r[0], r[1]]).collect();
        assert!(brute.len() >= pareto_front_k(&two).len());
    }

    #[test]
    fn objective_set_parsing_is_closed_and_typed() {
        assert_eq!(ObjectiveSet::parse_csv("power,area").unwrap(), ObjectiveSet::PowerArea);
        assert_eq!(ObjectiveSet::parse_csv("power, area").unwrap(), ObjectiveSet::PowerArea);
        assert_eq!(
            ObjectiveSet::parse_csv("energy,area,snr").unwrap(),
            ObjectiveSet::EnergyAreaSnr
        );
        assert_eq!(ObjectiveSet::default(), ObjectiveSet::PowerArea);
        for bad in ["", "energy", "energy,snr", "snr,area,energy", "power,area,snr", "turbo,area"]
        {
            let err = ObjectiveSet::parse_csv(bad).unwrap_err().to_string();
            assert!(
                err.contains("power,area") && err.contains("energy,area,snr"),
                "`{bad}`: {err}"
            );
        }
    }

    #[test]
    fn fold_range_visits_exactly_the_range_with_global_indices() {
        let model = AdcModel::default();
        let spec = small_spec();
        let all = run_sweep_prepared(&spec, &model, 1).unwrap();
        let n = spec.len();
        for (start, end) in [(0usize, 0usize), (0, 1), (5, 19), (n - 1, n), (0, n)] {
            for workers in [1usize, 4] {
                let visited = run_sweep_fold_range(
                    &spec,
                    &model,
                    workers,
                    start..end,
                    Vec::new,
                    |acc: &mut Vec<(usize, [u64; 4])>, i, q, m| {
                        assert_eq!(all[i].query, *q);
                        acc.push((i, m.to_bits()));
                    },
                    |mut a, b| {
                        a.extend(b);
                        a
                    },
                );
                let mut indices: Vec<usize> = visited.iter().map(|(i, _)| *i).collect();
                indices.sort_unstable();
                assert_eq!(indices, (start..end).collect::<Vec<_>>(), "{start}..{end}");
                for (i, bits) in visited {
                    assert_eq!(bits, all[i].metrics.to_bits());
                }
            }
        }
    }

    #[test]
    fn fast_tier_is_ulp_bounded_and_worker_independent() {
        use crate::util::fastmath::{MAX_ULP, ulp_distance};
        let model = AdcModel::default();
        // dense(5) has 600 points (600 % 4 == 0); small_spec has 36 — a
        // 4-remainder exercise rides in via fold ranges below.
        for spec in [SweepSpec::dense(5), small_spec()] {
            let exact = run_sweep_prepared(&spec, &model, 1).unwrap();
            let fast1 = run_sweep_prepared_tier(&spec, &model, 1, SweepTier::Fast).unwrap();
            let fast4 = run_sweep_prepared_tier(&spec, &model, 4, SweepTier::Fast).unwrap();
            assert_eq!(fast1.len(), exact.len());
            for ((e, f1), f4) in exact.iter().zip(&fast1).zip(&fast4) {
                assert_eq!(e.query, f1.query);
                // worker count must not change fast-tier bits
                assert_eq!(f1.metrics.to_bits(), f4.metrics.to_bits());
                for (a, b) in e.metrics.to_bits().iter().zip(f1.metrics.to_bits().iter()) {
                    let d = ulp_distance(f64::from_bits(*a), f64::from_bits(*b));
                    assert!(d <= MAX_ULP, "ulp {d} at {:?}", e.query);
                }
            }
        }
    }

    #[test]
    fn fast_fold_matches_fast_materialized_at_odd_ranges() {
        let model = AdcModel::default();
        let spec = small_spec();
        let all = run_sweep_prepared_tier(&spec, &model, 1, SweepTier::Fast).unwrap();
        let n = spec.len();
        // ranges with sub-quad remainders: tail and quad kernels must agree
        for (start, end) in [(0usize, 3usize), (1, 6), (5, 19), (n - 2, n), (0, n)] {
            for workers in [1usize, 4] {
                let visited = run_sweep_fold_range_tier(
                    &spec,
                    &model,
                    workers,
                    SweepTier::Fast,
                    start..end,
                    Vec::new,
                    |acc: &mut Vec<(usize, [u64; 4])>, i, q, m| {
                        assert_eq!(all[i].query, *q);
                        acc.push((i, m.to_bits()));
                    },
                    |mut a, b| {
                        a.extend(b);
                        a
                    },
                );
                for (i, bits) in visited {
                    assert_eq!(bits, all[i].metrics.to_bits(), "{start}..{end} index {i}");
                }
            }
        }
    }

    #[test]
    fn fast_native_evaluator_matches_prepared_fast_tier() {
        let model = AdcModel::default();
        let spec = small_spec();
        let prepared = run_sweep_prepared_tier(&spec, &model, 1, SweepTier::Fast).unwrap();
        for eval in [
            NativeEvaluator::serial(model).with_tier(SweepTier::Fast),
            NativeEvaluator::new(model).with_tier(SweepTier::Fast),
        ] {
            let out = eval.eval(&spec.points()).unwrap();
            assert_eq!(out.len(), prepared.len());
            for (a, b) in out.iter().zip(&prepared) {
                assert_eq!(a.to_bits(), b.metrics.to_bits());
            }
        }
    }

    #[test]
    fn fast_min_eap_agrees_with_exact_argmin_on_default_grid() {
        let model = AdcModel::default();
        let spec = SweepSpec::dense(6);
        let exact = sweep_min_eap(&spec, &model, 1).unwrap();
        let fast = sweep_min_eap_tier(&spec, &model, 4, SweepTier::Fast).unwrap();
        // the default grid's EAP minimum is not near-tied, so the
        // ULP-bounded tier must land on the same design point
        assert_eq!(exact.query, fast.query);
    }

    #[test]
    fn empty_grid_rolls_up_to_init() {
        let model = AdcModel::default();
        let spec = SweepSpec {
            enobs: vec![],
            total_throughputs: vec![1e9],
            tech_nms: vec![32.0],
            n_adcs: vec![1],
        };
        assert!(run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap().is_empty());
        assert!(run_sweep_prepared(&spec, &model, 4).unwrap().is_empty());
        assert!(sweep_min_eap(&spec, &model, 4).is_none());
        assert!(sweep_power_area_front(&spec, &model, 4).is_empty());
        assert!(
            sweep_energy_area_snr_front(&spec, &model, 4, &snr::SnrContext::default()).is_empty()
        );
    }
}
