//! Regeneration of every figure in the paper's evaluation (DESIGN.md §4).
//!
//! Each `figN_*` function produces the figure's underlying data through
//! the public APIs (survey → fit → model → mapper → rollup), and a
//! `render_figN` helper turns it into tables/plots. The figure benches
//! and the `cimdse figures` CLI subcommand both call these, so the paper
//! reproduction is a single code path asserted by integration tests.

use crate::adc::{AdcModel, AdcQuery};
use crate::arch::raella::{RaellaVariant, raella};
use crate::energy::{AreaScope, accel_area, eap, layer_energy};
use crate::error::Result;
use crate::mapper::map_layer;
use crate::report::{AsciiPlot, Table, sig};
use crate::survey::{SurveyDataset, pareto_near_filter, scale_to_tech};
use crate::survey::filters::nearest_enob_bin;
use crate::util::logspace::logspace;
use crate::workload::resnet18::{large_tensor_layer, resnet18, small_tensor_layer};
use crate::workload::{Layer, Workload};

/// The ENOB lines the paper draws in Figs. 2–3.
pub const FIG23_ENOBS: [f64; 3] = [4.0, 8.0, 12.0];

/// One model line of Fig. 2/3: (ENOB, points of (throughput, value)).
pub type Line = (f64, Vec<(f64, f64)>);

/// Data behind Fig. 2 (energy) or Fig. 3 (area).
#[derive(Clone, Debug)]
pub struct Fig23Data {
    /// Survey dots after 32 nm scaling + near-Pareto filtering:
    /// (throughput, value, nearest ENOB bin).
    pub dots: Vec<(f64, f64, f64)>,
    /// Model lines per ENOB bin.
    pub lines: Vec<Line>,
}

/// Fig. 2: published-ADC throughput vs energy with model bound lines.
pub fn fig2(survey: &SurveyDataset, model: &AdcModel, line_points: usize) -> Fig23Data {
    let scaled: Vec<_> = survey
        .records
        .iter()
        .map(|r| scale_to_tech(r, 32.0, &model.coefs))
        .collect();
    let near = pareto_near_filter(&scaled, 1.0, |r| r.energy_pj);
    let dots = near
        .iter()
        .map(|r| (r.throughput, r.energy_pj, nearest_enob_bin(r.enob, &FIG23_ENOBS)))
        .collect();
    let lines = FIG23_ENOBS
        .iter()
        .map(|&enob| {
            let pts = logspace(1e4, 2e10, line_points)
                .into_iter()
                .map(|f| {
                    let q = AdcQuery {
                        enob,
                        total_throughput: f,
                        tech_nm: 32.0,
                        n_adcs: 1,
                    };
                    (f, model.energy_pj_per_convert(&q))
                })
                .collect();
            (enob, pts)
        })
        .collect();
    Fig23Data { dots, lines }
}

/// Fig. 3: published-ADC throughput vs area with model lines.
pub fn fig3(survey: &SurveyDataset, model: &AdcModel, line_points: usize) -> Fig23Data {
    let scaled: Vec<_> = survey
        .records
        .iter()
        .map(|r| scale_to_tech(r, 32.0, &model.coefs))
        .collect();
    let near = pareto_near_filter(&scaled, 1.0, |r| r.area_um2);
    let dots = near
        .iter()
        .map(|r| (r.throughput, r.area_um2, nearest_enob_bin(r.enob, &FIG23_ENOBS)))
        .collect();
    let lines = FIG23_ENOBS
        .iter()
        .map(|&enob| {
            let pts = logspace(1e4, 2e10, line_points)
                .into_iter()
                .map(|f| {
                    let q = AdcQuery {
                        enob,
                        total_throughput: f,
                        tech_nm: 32.0,
                        n_adcs: 1,
                    };
                    (f, model.area_um2_per_adc(&q))
                })
                .collect();
            (enob, pts)
        })
        .collect();
    Fig23Data { dots, lines }
}

/// Render a Fig. 2/3 dataset as an ASCII log-log plot.
pub fn render_fig23(data: &Fig23Data, title: &str, y_label: &str) -> String {
    let mut plot = AsciiPlot::new(title, "throughput (converts/s)", y_label);
    let glyphs = ['·', 'o', '*'];
    for (i, &enob) in FIG23_ENOBS.iter().enumerate() {
        let pts: Vec<(f64, f64)> = data
            .dots
            .iter()
            .filter(|d| d.2 == enob)
            .map(|d| (d.0, d.1))
            .collect();
        plot = plot.series(&format!("{enob:.0}b survey"), glyphs[i], pts);
    }
    let line_glyphs = ['4', '8', 'C'];
    for (i, (enob, pts)) in data.lines.iter().enumerate() {
        plot = plot.series(&format!("{enob:.0}b model"), line_glyphs[i], pts.clone());
    }
    plot.render()
}

/// One Fig. 4 cell: a RAELLA variant on a layer group.
#[derive(Clone, Debug)]
pub struct Fig4Row {
    /// Layer group name ("large-tensor", "small-tensor", "all-layers").
    pub group: &'static str,
    /// Variant name (S/M/L/XL).
    pub variant: &'static str,
    /// Analog sum utilization (averaged over layers, weighted by MACs).
    pub utilization: f64,
    /// ADC energy (pJ).
    pub adc_pj: f64,
    /// Non-ADC energy (pJ).
    pub other_pj: f64,
    /// Total (pJ).
    pub total_pj: f64,
}

/// Fig. 4: full-accelerator energy for S/M/L/XL over the three layer groups.
pub fn fig4(model: &AdcModel) -> Result<Vec<Fig4Row>> {
    let net = resnet18();
    let groups: [(&'static str, Vec<Layer>); 3] = [
        ("large-tensor", vec![large_tensor_layer()]),
        ("small-tensor", vec![small_tensor_layer()]),
        ("all-layers", net.layers.clone()),
    ];
    let mut rows = Vec::new();
    // `&'static str` is Copy: bind the group name by value so it keeps its
    // 'static lifetime instead of borrowing through the loop reference.
    for &(group, ref layers) in &groups {
        for variant in RaellaVariant::ALL {
            let arch = raella(variant);
            let mut adc_pj = 0.0;
            let mut total_pj = 0.0;
            let mut util_weighted = 0.0;
            let mut macs = 0.0;
            for layer in layers {
                let e = layer_energy(&arch, model, layer)?;
                adc_pj += e.adc_pj;
                total_pj += e.total_pj();
                let m = map_layer(&arch, layer)?;
                util_weighted += m.utilization * layer.macs() as f64;
                macs += layer.macs() as f64;
            }
            rows.push(Fig4Row {
                group,
                variant: variant.name(),
                utilization: util_weighted / macs,
                adc_pj,
                other_pj: total_pj - adc_pj,
                total_pj,
            });
        }
    }
    Ok(rows)
}

/// Render Fig. 4 rows as a table.
pub fn render_fig4(rows: &[Fig4Row]) -> Table {
    let mut t = Table::new(vec![
        "layer-group",
        "variant",
        "utilization",
        "ADC (µJ)",
        "other (µJ)",
        "total (µJ)",
    ]);
    for r in rows {
        t.row(vec![
            r.group.to_string(),
            r.variant.to_string(),
            format!("{:.3}", r.utilization),
            sig(r.adc_pj / 1e6, 3),
            sig(r.other_pj / 1e6, 3),
            sig(r.total_pj / 1e6, 3),
        ]);
    }
    t
}

/// One Fig. 5 cell: EAP at (total throughput, n_adcs).
#[derive(Clone, Copy, Debug)]
pub struct Fig5Cell {
    /// Aggregate ADC throughput (converts/s).
    pub total_throughput: f64,
    /// Number of parallel ADCs.
    pub n_adcs: u32,
    /// Layer energy (pJ).
    pub energy_pj: f64,
    /// Array-group area (µm²).
    pub area_um2: f64,
    /// Energy-area product (pJ·µm²).
    pub eap: f64,
}

/// Fig. 5: accelerator EAP vs number of ADCs for varying throughputs, on
/// the paper's chosen ResNet18 layer (we use the large-tensor conv; the
/// Medium variant is the base architecture).
pub fn fig5(model: &AdcModel, throughput_steps: usize) -> Result<Vec<Fig5Cell>> {
    let layer = large_tensor_layer();
    let base = raella(RaellaVariant::Medium);
    let mut cells = Vec::new();
    for &total in &logspace(1.3e9, 40e9, throughput_steps) {
        for &n in &[1u32, 2, 4, 8, 16] {
            let mut arch = base.clone();
            arch.adc.n_adcs = n;
            arch.adc.total_throughput = total;
            let e = layer_energy(&arch, model, &layer)?;
            let m = map_layer(&arch, &layer)?;
            let a = accel_area(&arch, model, AreaScope::ArrayGroup { n_arrays: m.arrays_used });
            cells.push(Fig5Cell {
                total_throughput: total,
                n_adcs: n,
                energy_pj: e.total_pj(),
                area_um2: a.total_um2(),
                eap: eap(&e, &a),
            });
        }
    }
    Ok(cells)
}

/// Render Fig. 5 cells as a table with per-throughput optima marked.
pub fn render_fig5(cells: &[Fig5Cell]) -> Table {
    let mut t = Table::new(vec![
        "total throughput",
        "n_adcs",
        "energy (µJ)",
        "area (mm²)",
        "EAP (norm)",
        "optimal",
    ]);
    // Normalize EAP within each throughput row-group; mark the optimum.
    let mut throughputs: Vec<f64> = cells.iter().map(|c| c.total_throughput).collect();
    throughputs.dedup();
    for &tp in &throughputs {
        let group: Vec<&Fig5Cell> =
            cells.iter().filter(|c| c.total_throughput == tp).collect();
        let best = group
            .iter()
            .min_by(|a, b| a.eap.total_cmp(&b.eap))
            .map(|c| c.n_adcs)
            .unwrap();
        let min_eap = group.iter().map(|c| c.eap).fold(f64::MAX, f64::min);
        for c in &group {
            t.row(vec![
                format!("{:.2e}", c.total_throughput),
                c.n_adcs.to_string(),
                sig(c.energy_pj / 1e6, 3),
                format!("{:.4}", c.area_um2 / 1e6),
                format!("{:.2}", c.eap / min_eap),
                if c.n_adcs == best { "  <-- min EAP".into() } else { String::new() },
            ]);
        }
    }
    t
}

/// Whole-workload summary used by the end-to-end example: per-layer
/// energy/utilization rows for one architecture.
pub fn per_layer_table(
    model: &AdcModel,
    arch: &crate::arch::CimArch,
    net: &Workload,
) -> Result<Table> {
    let mut t = Table::new(vec![
        "layer",
        "rows(CRS)",
        "chunks",
        "util",
        "ADC (µJ)",
        "total (µJ)",
        "ADC frac",
    ]);
    for layer in &net.layers {
        let m = map_layer(&arch, layer)?;
        let e = layer_energy(&arch, model, layer)?;
        t.row(vec![
            layer.name.clone(),
            layer.weight_rows().to_string(),
            m.row_chunks.to_string(),
            format!("{:.3}", m.utilization),
            sig(e.adc_pj / 1e6, 3),
            sig(e.total_pj() / 1e6, 3),
            format!("{:.2}", e.adc_fraction()),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::survey::generator::{SurveyConfig, generate_survey};

    fn survey() -> SurveyDataset {
        generate_survey(&SurveyConfig::default())
    }

    #[test]
    fn fig2_has_dots_and_three_lines() {
        let d = fig2(&survey(), &AdcModel::default(), 25);
        assert_eq!(d.lines.len(), 3);
        assert!(d.dots.len() > 30, "only {} near-Pareto dots", d.dots.len());
        // Lines are monotone non-decreasing in throughput (flat then rising).
        for (_, pts) in &d.lines {
            assert!(pts.windows(2).all(|w| w[1].1 >= w[0].1 - 1e-9));
        }
    }

    #[test]
    fn fig2_lines_order_by_enob() {
        let d = fig2(&survey(), &AdcModel::default(), 10);
        for i in 0..d.lines[0].1.len() {
            assert!(d.lines[0].1[i].1 < d.lines[1].1[i].1);
            assert!(d.lines[1].1[i].1 < d.lines[2].1[i].1);
        }
    }

    #[test]
    fn fig3_area_increases_with_throughput_and_enob() {
        let d = fig3(&survey(), &AdcModel::default(), 10);
        for (_, pts) in &d.lines {
            assert!(pts.windows(2).all(|w| w[1].1 >= w[0].1 - 1e-9));
        }
        for i in 0..d.lines[0].1.len() {
            assert!(d.lines[0].1[i].1 < d.lines[2].1[i].1);
        }
    }

    #[test]
    fn fig4_shapes_match_paper_claims() {
        let rows = fig4(&AdcModel::default()).unwrap();
        assert_eq!(rows.len(), 12);
        let get = |g: &str, v: &str| {
            rows.iter().find(|r| r.group == g && r.variant == v).unwrap().clone()
        };
        // Large-tensor: summing more values reduces ADC energy (XL < S).
        assert!(get("large-tensor", "XL").adc_pj < get("large-tensor", "S").adc_pj);
        // Small-tensor: higher-ENOB ADCs cost more (XL > S).
        assert!(get("small-tensor", "XL").total_pj > get("small-tensor", "S").total_pj);
        // Overall: M or L is the best total.
        let all: Vec<Fig4Row> =
            rows.iter().filter(|r| r.group == "all-layers").cloned().collect();
        let best = all
            .iter()
            .min_by(|a, b| a.total_pj.total_cmp(&b.total_pj))
            .unwrap();
        assert!(
            best.variant == "M" || best.variant == "L",
            "best overall variant was {}",
            best.variant
        );
    }

    #[test]
    fn fig5_shapes_match_paper_claims() {
        let cells = fig5(&AdcModel::default(), 4).unwrap();
        // (1) Higher total throughput -> higher minimum EAP.
        let min_eap_at = |tp: f64| {
            cells
                .iter()
                .filter(|c| c.total_throughput == tp)
                .map(|c| c.eap)
                .fold(f64::MAX, f64::min)
        };
        let mut tps: Vec<f64> = cells.iter().map(|c| c.total_throughput).collect();
        tps.dedup();
        for w in tps.windows(2) {
            assert!(min_eap_at(w[1]) > min_eap_at(w[0]));
        }
        // (2) The number of ADCs can swing EAP by ~3x at some throughput.
        let max_swing = tps
            .iter()
            .map(|&tp| {
                let group: Vec<f64> = cells
                    .iter()
                    .filter(|c| c.total_throughput == tp)
                    .map(|c| c.eap)
                    .collect();
                group.iter().fold(f64::MIN, |a, &b| a.max(b))
                    / group.iter().fold(f64::MAX, |a, &b| a.min(b))
            })
            .fold(f64::MIN, f64::max);
        assert!(max_swing > 2.0, "EAP swing only {max_swing:.2}x");
        // (3) Optimal n_adcs grows with throughput demand.
        let opt = |tp: f64| {
            cells
                .iter()
                .filter(|c| c.total_throughput == tp)
                .min_by(|a, b| a.eap.total_cmp(&b.eap))
                .unwrap()
                .n_adcs
        };
        assert!(opt(*tps.last().unwrap()) > opt(tps[0]),
            "optimum did not grow: {} -> {}", opt(tps[0]), opt(*tps.last().unwrap()));
    }

    #[test]
    fn renders_do_not_panic_and_contain_content() {
        let model = AdcModel::default();
        let d2 = fig2(&survey(), &model, 10);
        assert!(render_fig23(&d2, "fig2", "pJ/convert").contains("model"));
        let t4 = render_fig4(&fig4(&model).unwrap());
        assert!(t4.render().contains("large-tensor"));
        let t5 = render_fig5(&fig5(&model, 3).unwrap());
        assert!(t5.render().contains("min EAP"));
        let tl = per_layer_table(&model, &raella(RaellaVariant::Medium), &resnet18()).unwrap();
        assert_eq!(tl.len(), 21);
    }
}
