//! Sharded multi-process sweeps.
//!
//! The streaming engine ([`super::run_sweep_fold`]) saturates one
//! machine; this
//! module turns it into the building block for multi-process scale-out:
//!
//! * [`ShardPlan`] partitions a [`SweepSpec`]'s index space into disjoint
//!   contiguous sub-ranges (stable under [`SweepSpec::point_at`] /
//!   `for_each_index_in_range`, so shard membership is a pure function of
//!   the spec and the shard count).
//! * [`ShardArtifact::compute`] runs one shard through the
//!   invariant-hoisted kernel ([`run_sweep_fold_range`]) into a
//!   [`SweepSummary`] — the streamed fold (per-metric extrema), min-EAP
//!   candidate, power/area [`StreamingFront`], and (for sweeps launched
//!   with a compute-SNR objective, [`ShardArtifact::compute_with`]) the
//!   tri-objective energy/area/−SNR [`FrontK`] — and serializes it as
//!   a self-describing JSON document via the [`crate::config::Value`]
//!   layer. Every payload float travels as its IEEE-754 bit pattern
//!   ([`f64_to_bits_hex`]), so nothing is lost at the process boundary.
//! * [`merge_shards`] folds any subset of artifacts back together. Each
//!   rollup is insensitive to encounter order (extrema under `total_cmp`,
//!   argmin with grid-index tie-break, the order-independent
//!   [`StreamingFront`]), so the merged result of a complete shard set is
//!   **bit-identical** to the single-process [`super::sweep_min_eap`] /
//!   [`super::sweep_power_area_front`] / fold outputs — asserted across
//!   real process boundaries by `tests/shard_roundtrip.rs`.
//!
//! Artifacts carry a fingerprint ([`sweep_fingerprint`]) over the exact
//! bits of the spec axes and model coefficients. Merging artifacts from
//! different sweeps is a typed error, and a completed artifact can be
//! recognized (fingerprint + range match) and skipped on re-run — the
//! resume semantics behind `cimdse sweep --shard i/N`.

use std::collections::BTreeMap;
use std::ops::Range;

use crate::adc::{AdcMetrics, AdcModel, AdcQuery, Coefficients};
use crate::config::{Value, f64_from_bits_hex, f64_to_bits_hex, parse_json};
use crate::error::{Error, Result};

use super::snr::SnrContext;
use super::sweep::SweepSpec;
use super::{
    EvaluatedPoint, FoldCtl, FrontK, StreamingFront, eap_candidate_better,
    run_sweep_fold_range_ctl,
};

/// Artifact schema version; bump on breaking payload changes.
const ARTIFACT_SCHEMA: usize = 1;

/// `kind` tag distinguishing shard artifacts from other JSON documents.
const ARTIFACT_KIND: &str = "cimdse-shard-artifact";

/// Metric names in [`AdcMetrics::to_bits`] field order — the keys used by
/// the extrema payload.
pub const METRIC_NAMES: [&str; 4] =
    ["energy_pj_per_convert", "area_um2_per_adc", "total_power_w", "total_area_um2"];

pub(crate) fn metric_values(m: &AdcMetrics) -> [f64; 4] {
    [m.energy_pj_per_convert, m.area_um2_per_adc, m.total_power_w, m.total_area_um2]
}

/// 64-bit FNV-1a over a byte string (stable, dependency-free).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x100_0000_01b3);
    }
    hash
}

/// Fingerprint of a (spec, model) pair: 16 hex digits of FNV-1a over
/// [`sweep_canonical`]. Two sweeps share a fingerprint iff their shards
/// are interchangeable — same grid order, same per-point bits. (FNV is
/// not collision-resistant, so [`merge_shards`] compares the full
/// canonical strings, not just this digest.)
pub fn sweep_fingerprint(spec: &SweepSpec, model: &AdcModel) -> String {
    sweep_fingerprint_with(spec, model, None)
}

/// [`sweep_fingerprint`] extended with the sweep's objective context:
/// when a compute-SNR objective is active its [`SnrContext`] enters the
/// canonical string, so a tri-objective resume can never accept a
/// classic power/area artifact (or one computed under a different
/// context) as complete. `None` is byte-identical to the classic
/// canonical string, hence to [`sweep_fingerprint`].
pub fn sweep_fingerprint_with(
    spec: &SweepSpec,
    model: &AdcModel,
    snr: Option<&SnrContext>,
) -> String {
    format!("{:016x}", fnv1a64(sweep_canonical_with(spec, model, snr).as_bytes()))
}

/// The canonical byte string a sweep is identified by: every axis value,
/// coefficient, and tuning offset as exact IEEE-754 bit patterns.
fn sweep_canonical(spec: &SweepSpec, model: &AdcModel) -> String {
    let mut canon = String::from("cimdse-sweep-v1;");
    let mut axis = |name: &str, xs: &[f64]| {
        canon.push_str(name);
        canon.push('=');
        for &x in xs {
            canon.push_str(&f64_to_bits_hex(x));
            canon.push(',');
        }
        canon.push(';');
    };
    axis("enobs", &spec.enobs);
    axis("total_throughputs", &spec.total_throughputs);
    axis("tech_nms", &spec.tech_nms);
    canon.push_str("n_adcs=");
    for &n in &spec.n_adcs {
        canon.push_str(&n.to_string());
        canon.push(',');
    }
    canon.push_str(";model=");
    for c in model.coefs.to_vec() {
        canon.push_str(&f64_to_bits_hex(c));
        canon.push(',');
    }
    canon.push_str(&f64_to_bits_hex(model.energy_offset_decades));
    canon.push(',');
    canon.push_str(&f64_to_bits_hex(model.area_offset_decades));
    canon
}

/// [`sweep_canonical`] plus the optional SNR objective context. With
/// `None` this *is* `sweep_canonical` (same bytes — pre-existing
/// fingerprints and resume directories stay valid); with `Some` the
/// context's integer attributes are appended, so sweeps that differ only
/// in objective set or SNR context never share a canonical string.
fn sweep_canonical_with(spec: &SweepSpec, model: &AdcModel, snr: Option<&SnrContext>) -> String {
    let mut canon = sweep_canonical(spec, model);
    if let Some(ctx) = snr {
        canon.push_str(";snr=n_sum:");
        canon.push_str(&ctx.n_sum.to_string());
        canon.push_str(",cell_bits:");
        canon.push_str(&ctx.cell_bits.to_string());
    }
    canon
}

/// A validated `index/n_shards` selection (e.g. from `--shard 2/7`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSelector {
    index: usize,
    n_shards: usize,
}

impl ShardSelector {
    /// Build a selector, rejecting `n_shards == 0` and out-of-range
    /// indices with typed errors.
    pub fn new(index: usize, n_shards: usize) -> Result<ShardSelector> {
        if n_shards == 0 {
            return Err(Error::Config("shard count must be >= 1, got 0".into()));
        }
        if index >= n_shards {
            return Err(Error::Config(format!(
                "shard index {index} out of range for {n_shards} shards (valid: 0..{n_shards})"
            )));
        }
        Ok(ShardSelector { index, n_shards })
    }

    /// Parse an `index/n_shards` spec like `"2/7"`.
    pub fn parse(s: &str) -> Result<ShardSelector> {
        let (index, n_shards) = s.split_once('/').ok_or_else(|| {
            Error::Config(format!("shard spec `{s}` is not of the form `index/n_shards`"))
        })?;
        let index: usize = index.trim().parse().map_err(|_| {
            Error::Config(format!("shard spec `{s}`: `{index}` is not a shard index"))
        })?;
        let n_shards: usize = n_shards.trim().parse().map_err(|_| {
            Error::Config(format!("shard spec `{s}`: `{n_shards}` is not a shard count"))
        })?;
        ShardSelector::new(index, n_shards)
    }

    /// The selected shard index (`< n_shards`).
    pub fn index(&self) -> usize {
        self.index
    }

    /// Total shard count (`>= 1`).
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }
}

impl std::fmt::Display for ShardSelector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.index, self.n_shards)
    }
}

/// A partition of a spec's index space into `n_shards` disjoint
/// contiguous ranges whose union is exactly `0..len`. Ranges are balanced
/// (sizes differ by at most one, larger shards first), and depend only on
/// `(len, n_shards)` — every process planning the same spec computes the
/// same partition.
#[derive(Clone, Copy, Debug)]
pub struct ShardPlan {
    len: usize,
    n_shards: usize,
}

impl ShardPlan {
    /// Plan `n_shards` sub-ranges over `spec`'s grid. Typed errors for a
    /// zero shard count, a grid whose axis product overflows `usize`, and
    /// a grid too large for f64-exact artifact indices (> 2^53 points —
    /// such a sweep could not finish anyway).
    pub fn new(spec: &SweepSpec, n_shards: usize) -> Result<ShardPlan> {
        if n_shards == 0 {
            return Err(Error::Config("cannot plan a sweep over 0 shards".into()));
        }
        let len = spec.checked_len().ok_or_else(|| {
            Error::Numeric(
                "sweep grid length overflows usize; split the spec into sub-range specs".into(),
            )
        })?;
        if len as u64 > (1u64 << 53) {
            return Err(Error::Numeric(format!(
                "sweep grid has {len} points; shard artifacts index points as f64-exact \
                 integers (limit 2^53)"
            )));
        }
        Ok(ShardPlan { len, n_shards })
    }

    /// Total grid points being partitioned.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the underlying grid is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of shards in the plan.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// The index sub-range of shard `shard`. Panics if `shard` is out of
    /// range (construct selectors via [`ShardSelector`] to get a typed
    /// error instead).
    pub fn range(&self, shard: usize) -> Range<usize> {
        assert!(
            shard < self.n_shards,
            "shard {shard} out of range for a {}-shard plan",
            self.n_shards
        );
        let base = self.len / self.n_shards;
        let extra = self.len % self.n_shards;
        let start = shard * base + shard.min(extra);
        let end = start + base + usize::from(shard < extra);
        start..end
    }

    /// All shard ranges in order (disjoint, covering `0..len`).
    pub fn ranges(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.n_shards).map(|i| self.range(i))
    }
}

/// Per-metric minima/maxima over the points a summary absorbed, under
/// `total_cmp` ordering (order-independent even for NaN/±inf metrics).
/// Indexed in [`METRIC_NAMES`] order.
#[derive(Clone, Copy, Debug)]
pub struct MetricExtrema {
    /// Per-metric minimum.
    pub min: [f64; 4],
    /// Per-metric maximum.
    pub max: [f64; 4],
}

/// The streamed rollup a shard (or a whole single-process sweep) carries:
/// point count, per-metric extrema, the min-EAP candidate (with its grid
/// index for deterministic tie-breaks), the power/area Pareto front, and
/// — when the sweep was launched with a compute-SNR objective
/// ([`SweepSummary::with_snr`]) — the tri-objective
/// energy/area/−SNR front with its [`SnrContext`].
///
/// Every component is insensitive to fold/merge order, so
/// `merge(a, b) == merge(b, a)` bit-for-bit and a shard-wise computation
/// merged in any order reproduces [`SweepSummary::compute`] exactly.
#[derive(Clone, Debug, Default)]
pub struct SweepSummary {
    count: usize,
    extrema: Option<MetricExtrema>,
    best: Option<(usize, f64, EvaluatedPoint)>,
    front: StreamingFront,
    snr: Option<(SnrContext, FrontK<3>)>,
}

impl SweepSummary {
    /// Empty summary (the fold identity: `merge(new(), s) == s`).
    pub fn new() -> SweepSummary {
        SweepSummary::default()
    }

    /// Empty summary that additionally accumulates the tri-objective
    /// energy/area/−SNR front under `ctx`. The SNR objective is pushed
    /// negated so all three objectives minimize. The context persists
    /// through empty shards, so every shard of a tri-objective sweep
    /// carries (and fingerprints) the same context.
    pub fn with_snr(ctx: SnrContext) -> SweepSummary {
        SweepSummary { snr: Some((ctx, FrontK::new())), ..SweepSummary::default() }
    }

    /// Absorb one evaluated grid point.
    pub fn absorb(&mut self, index: usize, query: &AdcQuery, metrics: &AdcMetrics) {
        self.count += 1;
        let vals = metric_values(metrics);
        match &mut self.extrema {
            None => self.extrema = Some(MetricExtrema { min: vals, max: vals }),
            Some(e) => {
                for k in 0..4 {
                    if vals[k].total_cmp(&e.min[k]).is_lt() {
                        e.min[k] = vals[k];
                    }
                    if vals[k].total_cmp(&e.max[k]).is_gt() {
                        e.max[k] = vals[k];
                    }
                }
            }
        }
        // Same EAP expression and comparator as `sweep_min_eap`, so the
        // merged argmin cannot drift from the single-process path.
        let eap = metrics.energy_pj_per_convert * metrics.total_area_um2;
        if self
            .best
            .as_ref()
            .map_or(true, |cur| eap_candidate_better((index, eap), (cur.0, cur.1)))
        {
            self.best = Some((index, eap, EvaluatedPoint { query: *query, metrics: *metrics }));
        }
        self.front.push(metrics.total_power_w, metrics.total_area_um2, index);
        if let Some((ctx, front)) = &mut self.snr {
            front.push(
                [
                    metrics.energy_pj_per_convert,
                    metrics.total_area_um2,
                    -ctx.compute_snr_db(query.enob),
                ],
                index,
            );
        }
    }

    /// Combine two summaries (commutative and associative).
    pub fn merge(mut self, other: SweepSummary) -> SweepSummary {
        self.count += other.count;
        self.extrema = match (self.extrema, other.extrema) {
            (Some(mut a), Some(b)) => {
                for k in 0..4 {
                    if b.min[k].total_cmp(&a.min[k]).is_lt() {
                        a.min[k] = b.min[k];
                    }
                    if b.max[k].total_cmp(&a.max[k]).is_gt() {
                        a.max[k] = b.max[k];
                    }
                }
                Some(a)
            }
            (a, None) => a,
            (None, b) => b,
        };
        self.best = match (self.best, other.best) {
            (Some(a), Some(b)) => {
                Some(if eap_candidate_better((a.0, a.1), (b.0, b.1)) { a } else { b })
            }
            (a, None) => a,
            (None, b) => b,
        };
        self.front = self.front.merge(other.front);
        // Total even on mismatched operands: the left context wins when
        // both sides carry one. Callers that must not conflate contexts
        // ([`merge_shards`]) compare the full canonical strings first.
        self.snr = match (self.snr.take(), other.snr) {
            (Some((ctx, a)), Some((_, b))) => Some((ctx, a.merge(b))),
            (a, None) => a,
            (None, b) => b,
        };
        self
    }

    /// Streamed summary of a contiguous index range of `spec`'s grid.
    pub fn compute_range(
        spec: &SweepSpec,
        model: &AdcModel,
        workers: usize,
        range: Range<usize>,
    ) -> SweepSummary {
        SweepSummary::compute_range_ctl(spec, model, workers, range, FoldCtl::default())
            .expect("a fold without a cancel token cannot be cancelled")
    }

    /// [`SweepSummary::compute_range`] under a [`FoldCtl`]: cancellable
    /// at chunk granularity with progress reporting. Returns `None` iff
    /// the control's token tripped; a completed summary is bit-identical
    /// to the uncontrolled one (the controls never reach the fold).
    pub fn compute_range_ctl(
        spec: &SweepSpec,
        model: &AdcModel,
        workers: usize,
        range: Range<usize>,
        ctl: FoldCtl<'_>,
    ) -> Option<SweepSummary> {
        SweepSummary::compute_range_ctl_with(spec, model, workers, range, ctl, None)
    }

    /// [`SweepSummary::compute_range_ctl`] with an optional compute-SNR
    /// objective context. `None` is the classic power/area-only summary
    /// (bit-identical payload); `Some(ctx)` additionally streams the
    /// tri-objective front.
    pub fn compute_range_ctl_with(
        spec: &SweepSpec,
        model: &AdcModel,
        workers: usize,
        range: Range<usize>,
        ctl: FoldCtl<'_>,
        snr: Option<SnrContext>,
    ) -> Option<SweepSummary> {
        run_sweep_fold_range_ctl(
            spec,
            model,
            workers,
            super::SweepTier::Exact,
            range,
            ctl,
            move || match snr {
                None => SweepSummary::new(),
                Some(ctx) => SweepSummary::with_snr(ctx),
            },
            |acc: &mut SweepSummary, i, q, m| acc.absorb(i, q, m),
            SweepSummary::merge,
        )
    }

    /// Streamed summary of the whole grid — the single-process reference
    /// every complete shard merge must reproduce bit-identically.
    pub fn compute(spec: &SweepSpec, model: &AdcModel, workers: usize) -> SweepSummary {
        SweepSummary::compute_with(spec, model, workers, None)
    }

    /// [`SweepSummary::compute`] with an optional compute-SNR objective
    /// context (see [`SweepSummary::compute_range_ctl_with`]).
    pub fn compute_with(
        spec: &SweepSpec,
        model: &AdcModel,
        workers: usize,
        snr: Option<SnrContext>,
    ) -> SweepSummary {
        let len = spec.checked_len().expect(
            "sweep grid length overflows usize; split the spec into sub-range specs",
        );
        SweepSummary::compute_range_ctl_with(spec, model, workers, 0..len, FoldCtl::default(), snr)
            .expect("a fold without a cancel token cannot be cancelled")
    }

    /// Points absorbed.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Per-metric extrema (`None` iff no points were absorbed).
    pub fn extrema(&self) -> Option<&MetricExtrema> {
        self.extrema.as_ref()
    }

    /// The minimum-EAP design point (ties broken toward the lowest grid
    /// index) — equals [`super::sweep_min_eap`] on the same coverage.
    pub fn min_eap(&self) -> Option<&EvaluatedPoint> {
        self.best.as_ref().map(|(_, _, p)| p)
    }

    /// Grid index of the min-EAP point.
    pub fn min_eap_index(&self) -> Option<usize> {
        self.best.as_ref().map(|(i, _, _)| *i)
    }

    /// The power/area Pareto front accumulated so far.
    pub fn front(&self) -> &StreamingFront {
        &self.front
    }

    /// Front indices in [`super::pareto_front`] order — equals
    /// [`super::sweep_power_area_front`] on the same coverage.
    pub fn front_indices(&self) -> Vec<usize> {
        self.front.indices()
    }

    /// The compute-SNR objective context, iff this summary was built with
    /// one ([`SweepSummary::with_snr`]).
    pub fn snr_context(&self) -> Option<SnrContext> {
        self.snr.as_ref().map(|(ctx, _)| *ctx)
    }

    /// The accumulated tri-objective energy/area/−SNR front, iff the
    /// summary carries the SNR objective.
    pub fn snr_front(&self) -> Option<&FrontK<3>> {
        self.snr.as_ref().map(|(_, front)| front)
    }

    /// Tri-objective front indices — equals
    /// [`super::sweep_energy_area_snr_front`] on the same coverage.
    pub fn snr_front_indices(&self) -> Option<Vec<usize>> {
        self.snr.as_ref().map(|(_, front)| front.indices())
    }

    /// Canonical [`Value`] payload. All floats travel as IEEE-754 bit
    /// patterns; two summaries are bit-identical iff their serialized
    /// JSON strings are byte-identical (tables are sorted), which is what
    /// the CI round-trip diffs.
    pub fn to_value(&self) -> Value {
        let mut map = BTreeMap::new();
        map.insert("schema".to_string(), Value::Number(ARTIFACT_SCHEMA as f64));
        map.insert("count".to_string(), Value::Number(self.count as f64));
        map.insert(
            "extrema".to_string(),
            match &self.extrema {
                None => Value::Null,
                Some(e) => {
                    let mut t = BTreeMap::new();
                    for (k, name) in METRIC_NAMES.iter().enumerate() {
                        let mut pair = BTreeMap::new();
                        pair.insert("min".to_string(), Value::String(f64_to_bits_hex(e.min[k])));
                        pair.insert("max".to_string(), Value::String(f64_to_bits_hex(e.max[k])));
                        t.insert(name.to_string(), Value::Table(pair));
                    }
                    Value::Table(t)
                }
            },
        );
        map.insert(
            "min_eap".to_string(),
            match &self.best {
                None => Value::Null,
                Some((index, eap, point)) => {
                    let mut t = BTreeMap::new();
                    t.insert("index".to_string(), Value::Number(*index as f64));
                    t.insert("eap".to_string(), Value::String(f64_to_bits_hex(*eap)));
                    t.insert("query".to_string(), query_to_value(&point.query));
                    t.insert("metrics".to_string(), metrics_to_value(&point.metrics));
                    Value::Table(t)
                }
            },
        );
        map.insert("front".to_string(), self.front.to_value());
        // The snr_front key is ABSENT (not null) when the SNR objective
        // is off, so classic power/area payloads keep their exact
        // pre-existing bytes (CI diffs them against golden shards).
        if let Some((ctx, front)) = &self.snr {
            let mut t = BTreeMap::new();
            t.insert("context".to_string(), ctx.to_value());
            t.insert("front".to_string(), front.to_value());
            map.insert("snr_front".to_string(), Value::Table(t));
        }
        Value::Table(map)
    }

    /// Inverse of [`SweepSummary::to_value`], with typed errors.
    pub fn from_value(v: &Value) -> Result<SweepSummary> {
        let schema = v.require_usize("schema")?;
        if schema != ARTIFACT_SCHEMA {
            return Err(Error::Config(format!("unsupported summary schema {schema}")));
        }
        let count = v.require_usize("count")?;
        let extrema = match v.get("extrema") {
            None | Some(Value::Null) => None,
            Some(e) => {
                let mut min = [0.0f64; 4];
                let mut max = [0.0f64; 4];
                for (k, name) in METRIC_NAMES.iter().enumerate() {
                    min[k] = hex_field(e, &format!("{name}.min"))?;
                    max[k] = hex_field(e, &format!("{name}.max"))?;
                }
                Some(MetricExtrema { min, max })
            }
        };
        let best = match v.get("min_eap") {
            None | Some(Value::Null) => None,
            Some(b) => {
                let index = b.require_usize("index")?;
                let eap = hex_field(b, "eap")?;
                let query = query_from_value(
                    b.get("query")
                        .ok_or_else(|| Error::Config("min_eap payload lacks `query`".into()))?,
                )?;
                let metrics = metrics_from_value(
                    b.get("metrics")
                        .ok_or_else(|| Error::Config("min_eap payload lacks `metrics`".into()))?,
                )?;
                Some((index, eap, EvaluatedPoint { query, metrics }))
            }
        };
        let front = StreamingFront::from_value(
            v.get("front")
                .ok_or_else(|| Error::Config("summary payload lacks `front`".into()))?,
        )?;
        let snr = match v.get("snr_front") {
            None | Some(Value::Null) => None,
            Some(s) => {
                let ctx = SnrContext::from_value(s.get("context").ok_or_else(|| {
                    Error::Config("snr_front payload lacks `context`".into())
                })?)?;
                let tri = FrontK::<3>::from_value(s.get("front").ok_or_else(|| {
                    Error::Config("snr_front payload lacks `front`".into())
                })?)?;
                Some((ctx, tri))
            }
        };
        if count == 0
            && (extrema.is_some()
                || best.is_some()
                || !front.is_empty()
                || snr.as_ref().is_some_and(|(_, f)| !f.is_empty()))
        {
            return Err(Error::Config(
                "summary claims 0 points but carries a non-empty payload".into(),
            ));
        }
        Ok(SweepSummary { count, extrema, best, front, snr })
    }

    /// The canonical JSON text of [`SweepSummary::to_value`].
    pub fn to_json_string(&self) -> Result<String> {
        self.to_value().to_json_string()
    }
}

/// Fetch a bit-pattern-encoded f64 at a dotted path.
fn hex_field(v: &Value, path: &str) -> Result<f64> {
    f64_from_bits_hex(v.require_str(path)?)
}

/// FNV-1a over a summary's canonical JSON — the artifact's payload
/// checksum ([`ShardArtifact`] stores it as `summary_fnv`), so a
/// truncated or hand-edited payload fails to load instead of silently
/// skewing a merge. Serialization is total here: every float travels as
/// a bit-hex string and the only `Value::Number`s are finite usize
/// casts, so the canonical text always exists.
fn summary_checksum(summary: &SweepSummary) -> String {
    let canon = summary
        .to_json_string()
        .expect("summary serialization is total (bit-hex floats, finite counts)");
    format!("{:016x}", fnv1a64(canon.as_bytes()))
}

fn query_to_value(q: &AdcQuery) -> Value {
    let mut map = BTreeMap::new();
    map.insert("enob".to_string(), Value::String(f64_to_bits_hex(q.enob)));
    map.insert(
        "total_throughput".to_string(),
        Value::String(f64_to_bits_hex(q.total_throughput)),
    );
    map.insert("tech_nm".to_string(), Value::String(f64_to_bits_hex(q.tech_nm)));
    map.insert("n_adcs".to_string(), Value::Number(q.n_adcs as f64));
    Value::Table(map)
}

fn query_from_value(v: &Value) -> Result<AdcQuery> {
    let n_adcs = v.require_usize("n_adcs")?;
    if n_adcs > u32::MAX as usize {
        return Err(Error::Config(format!("query n_adcs {n_adcs} exceeds u32")));
    }
    Ok(AdcQuery {
        enob: hex_field(v, "enob")?,
        total_throughput: hex_field(v, "total_throughput")?,
        tech_nm: hex_field(v, "tech_nm")?,
        n_adcs: n_adcs as u32,
    })
}

fn metrics_to_value(m: &AdcMetrics) -> Value {
    let vals = metric_values(m);
    let mut map = BTreeMap::new();
    for (k, name) in METRIC_NAMES.iter().enumerate() {
        map.insert(name.to_string(), Value::String(f64_to_bits_hex(vals[k])));
    }
    Value::Table(map)
}

fn metrics_from_value(v: &Value) -> Result<AdcMetrics> {
    Ok(AdcMetrics {
        energy_pj_per_convert: hex_field(v, METRIC_NAMES[0])?,
        area_um2_per_adc: hex_field(v, METRIC_NAMES[1])?,
        total_power_w: hex_field(v, METRIC_NAMES[2])?,
        total_area_um2: hex_field(v, METRIC_NAMES[3])?,
    })
}

/// Fingerprint of a model alone: 16 hex digits of FNV-1a over the
/// model's canonical JSON ([`model_to_value`] — every coefficient and
/// tuning offset as IEEE-754 bit-hex, tables sorted). Bit-identical
/// models always share a fingerprint; FNV-1a is *not*
/// collision-resistant, so consumers that must never conflate two
/// models (the `service::` prepared-model cache) compare the model
/// bits as well.
pub fn model_fingerprint(model: &AdcModel) -> String {
    let canon = model_to_value(model)
        .to_json_string()
        .expect("model serialization is total (bit-hex floats)");
    format!("{:016x}", fnv1a64(canon.as_bytes()))
}

pub(crate) fn model_to_value(model: &AdcModel) -> Value {
    let mut map = BTreeMap::new();
    map.insert(
        "coefs".to_string(),
        Value::Array(
            model
                .coefs
                .to_vec()
                .into_iter()
                .map(|c| Value::String(f64_to_bits_hex(c)))
                .collect(),
        ),
    );
    map.insert(
        "energy_offset_decades".to_string(),
        Value::String(f64_to_bits_hex(model.energy_offset_decades)),
    );
    map.insert(
        "area_offset_decades".to_string(),
        Value::String(f64_to_bits_hex(model.area_offset_decades)),
    );
    Value::Table(map)
}

pub(crate) fn model_from_value(v: &Value) -> Result<AdcModel> {
    let arr = v
        .get("coefs")
        .and_then(Value::as_array)
        .ok_or_else(|| Error::Config("model payload lacks a `coefs` array".into()))?;
    if arr.len() != 11 {
        return Err(Error::Config(format!(
            "model payload has {} coefficients, want 11",
            arr.len()
        )));
    }
    let coefs = arr
        .iter()
        .enumerate()
        .map(|(i, item)| {
            f64_from_bits_hex(item.as_str().ok_or_else(|| {
                Error::Config(format!("model coefficient {i} is not a bit string"))
            })?)
        })
        .collect::<Result<Vec<f64>>>()?;
    Ok(AdcModel {
        coefs: Coefficients::from_slice(&coefs),
        energy_offset_decades: hex_field(v, "energy_offset_decades")?,
        area_offset_decades: hex_field(v, "area_offset_decades")?,
    })
}

/// The on-disk file-name convention for shard `index`'s artifact —
/// `shard_<index>.json`. One definition shared by `cimdse sweep --shard`
/// (its default `--out`) and the distributed launcher's artifact
/// directory, so a directory written by either is resumable by both.
pub fn artifact_file_name(index: usize) -> String {
    format!("shard_{index}.json")
}

/// One shard's completed work: the summary over its index sub-range plus
/// everything needed to validate and merge it later (fingerprint, the
/// full spec and model, the shard geometry).
#[derive(Clone, Debug)]
pub struct ShardArtifact {
    fingerprint: String,
    selector: ShardSelector,
    start: usize,
    end: usize,
    total: usize,
    spec: SweepSpec,
    model: AdcModel,
    summary: SweepSummary,
}

impl ShardArtifact {
    /// Run shard `selector` of `spec` through the streaming kernel.
    pub fn compute(
        spec: &SweepSpec,
        model: &AdcModel,
        selector: ShardSelector,
        workers: usize,
    ) -> Result<ShardArtifact> {
        ShardArtifact::compute_with(spec, model, selector, workers, None)
    }

    /// [`ShardArtifact::compute`] with an optional compute-SNR objective
    /// context: `Some(ctx)` yields an artifact whose summary carries the
    /// tri-objective front and whose fingerprint covers `ctx`
    /// ([`sweep_fingerprint_with`]).
    pub fn compute_with(
        spec: &SweepSpec,
        model: &AdcModel,
        selector: ShardSelector,
        workers: usize,
        snr: Option<SnrContext>,
    ) -> Result<ShardArtifact> {
        ShardArtifact::compute_ctl_with(spec, model, selector, workers, FoldCtl::default(), snr)?
            .ok_or_else(|| {
                Error::Runtime("a fold without a cancel token cannot be cancelled".into())
            })
    }

    /// [`ShardArtifact::compute`] under a [`FoldCtl`]: cancellable at
    /// chunk granularity with progress reporting. `Ok(None)` means the
    /// control's token tripped mid-shard; a completed artifact is
    /// byte-identical to the uncontrolled one.
    pub fn compute_ctl(
        spec: &SweepSpec,
        model: &AdcModel,
        selector: ShardSelector,
        workers: usize,
        ctl: FoldCtl<'_>,
    ) -> Result<Option<ShardArtifact>> {
        ShardArtifact::compute_ctl_with(spec, model, selector, workers, ctl, None)
    }

    /// [`ShardArtifact::compute_ctl`] with an optional compute-SNR
    /// objective context (see [`ShardArtifact::compute_with`]).
    pub fn compute_ctl_with(
        spec: &SweepSpec,
        model: &AdcModel,
        selector: ShardSelector,
        workers: usize,
        ctl: FoldCtl<'_>,
        snr: Option<SnrContext>,
    ) -> Result<Option<ShardArtifact>> {
        if let Some(ctx) = &snr {
            ctx.validate()?;
        }
        let plan = ShardPlan::new(spec, selector.n_shards())?;
        let range = plan.range(selector.index());
        let Some(summary) =
            SweepSummary::compute_range_ctl_with(spec, model, workers, range.clone(), ctl, snr)
        else {
            return Ok(None);
        };
        Ok(Some(ShardArtifact {
            fingerprint: sweep_fingerprint_with(spec, model, snr.as_ref()),
            selector,
            start: range.start,
            end: range.end,
            total: plan.len(),
            spec: spec.clone(),
            model: *model,
            summary,
        }))
    }

    /// The sweep fingerprint this shard belongs to.
    pub fn fingerprint(&self) -> &str {
        &self.fingerprint
    }

    /// Which shard of how many this artifact is.
    pub fn selector(&self) -> ShardSelector {
        self.selector
    }

    /// The grid index sub-range this shard covered.
    pub fn range(&self) -> Range<usize> {
        self.start..self.end
    }

    /// Total grid points of the full sweep.
    pub fn total(&self) -> usize {
        self.total
    }

    /// The embedded sweep spec.
    pub fn spec(&self) -> &SweepSpec {
        &self.spec
    }

    /// The embedded model.
    pub fn model(&self) -> &AdcModel {
        &self.model
    }

    /// The shard's streamed summary.
    pub fn summary(&self) -> &SweepSummary {
        &self.summary
    }

    /// Serialize as a self-describing [`Value`] document.
    pub fn to_value(&self) -> Value {
        let mut shard = BTreeMap::new();
        shard.insert("index".to_string(), Value::Number(self.selector.index() as f64));
        shard.insert("n_shards".to_string(), Value::Number(self.selector.n_shards() as f64));
        shard.insert("start".to_string(), Value::Number(self.start as f64));
        shard.insert("end".to_string(), Value::Number(self.end as f64));
        shard.insert("total".to_string(), Value::Number(self.total as f64));
        let mut map = BTreeMap::new();
        map.insert("kind".to_string(), Value::String(ARTIFACT_KIND.to_string()));
        map.insert("schema".to_string(), Value::Number(ARTIFACT_SCHEMA as f64));
        map.insert("fingerprint".to_string(), Value::String(self.fingerprint.clone()));
        map.insert("shard".to_string(), Value::Table(shard));
        map.insert("spec".to_string(), self.spec.to_value());
        map.insert("model".to_string(), model_to_value(&self.model));
        map.insert("summary".to_string(), self.summary.to_value());
        map.insert("summary_fnv".to_string(), Value::String(summary_checksum(&self.summary)));
        Value::Table(map)
    }

    /// Parse and validate an artifact document. Beyond shape errors, this
    /// re-derives the fingerprint and the shard's planned range from the
    /// embedded spec/model and rejects any disagreement with the stored
    /// values — a truncated or hand-edited artifact fails loudly instead
    /// of silently skewing a merge.
    pub fn from_value(v: &Value) -> Result<ShardArtifact> {
        match v.get("kind").and_then(Value::as_str) {
            Some(ARTIFACT_KIND) => {}
            other => {
                return Err(Error::Config(format!(
                    "not a shard artifact (kind = {other:?}, want `{ARTIFACT_KIND}`)"
                )));
            }
        }
        let schema = v.require_usize("schema")?;
        if schema != ARTIFACT_SCHEMA {
            return Err(Error::Config(format!("unsupported shard artifact schema {schema}")));
        }
        let fingerprint = v.require_str("fingerprint")?.to_string();
        let spec = SweepSpec::from_value(
            v.get("spec").ok_or_else(|| Error::Config("artifact lacks `spec`".into()))?,
        )?;
        let model = model_from_value(
            v.get("model").ok_or_else(|| Error::Config("artifact lacks `model`".into()))?,
        )?;
        let selector =
            ShardSelector::new(v.require_usize("shard.index")?, v.require_usize("shard.n_shards")?)?;
        let start = v.require_usize("shard.start")?;
        let end = v.require_usize("shard.end")?;
        let total = v.require_usize("shard.total")?;
        let plan = ShardPlan::new(&spec, selector.n_shards())?;
        let planned = plan.range(selector.index());
        if total != plan.len() || start != planned.start || end != planned.end {
            return Err(Error::Config(format!(
                "shard {selector} claims range {start}..{end} of {total} points but the \
                 embedded spec plans {}..{} of {}",
                planned.start,
                planned.end,
                plan.len()
            )));
        }
        let summary = SweepSummary::from_value(
            v.get("summary").ok_or_else(|| Error::Config("artifact lacks `summary`".into()))?,
        )?;
        // The fingerprint covers the objective context too, so it can
        // only be re-derived once the summary (which carries any
        // SnrContext) is parsed. A tri-objective artifact therefore
        // never masquerades as a classic one or vice versa.
        let expected = sweep_fingerprint_with(&spec, &model, summary.snr_context().as_ref());
        if fingerprint != expected {
            return Err(Error::Config(format!(
                "shard artifact fingerprint `{fingerprint}` does not match its own \
                 spec/model (expect `{expected}`) — artifact corrupted or hand-edited"
            )));
        }
        // Payload integrity: the stored checksum must match the parsed
        // summary's canonical serialization (round-tripping canonical
        // JSON is the identity, so any edited/corrupted byte of the
        // payload shows up here).
        let stored_fnv = v.require_str("summary_fnv")?;
        let actual_fnv = summary_checksum(&summary);
        if stored_fnv != actual_fnv {
            return Err(Error::Config(format!(
                "shard {selector} summary checksum `{stored_fnv}` does not match its \
                 payload (expect `{actual_fnv}`) — summary corrupted or hand-edited"
            )));
        }
        if summary.count() != end - start {
            return Err(Error::Config(format!(
                "shard {selector} summary covers {} points, want {} for range {start}..{end}",
                summary.count(),
                end - start
            )));
        }
        // Every payload index must fall inside the shard's own range.
        if let Some(i) = summary.min_eap_index() {
            if !(start..end).contains(&i) {
                return Err(Error::Config(format!(
                    "shard {selector} min-EAP index {i} outside its range {start}..{end}"
                )));
            }
        }
        for &(_, _, i) in summary.front().points() {
            if !(start..end).contains(&i) {
                return Err(Error::Config(format!(
                    "shard {selector} front index {i} outside its range {start}..{end}"
                )));
            }
        }
        if let Some(front) = summary.snr_front() {
            for &(_, i) in front.points() {
                if !(start..end).contains(&i) {
                    return Err(Error::Config(format!(
                        "shard {selector} snr front index {i} outside its range {start}..{end}"
                    )));
                }
            }
        }
        Ok(ShardArtifact { fingerprint, selector, start, end, total, spec, model, summary })
    }

    /// The artifact as canonical JSON text (newline-terminated).
    pub fn to_json_string(&self) -> Result<String> {
        Ok(self.to_value().to_json_string()? + "\n")
    }

    /// Write the artifact to `path`.
    pub fn write(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json_string()?)
            .map_err(|e| Error::Config(format!("cannot write shard artifact {path}: {e}")))
    }

    /// Load and validate an artifact from `path`.
    pub fn load(path: &str) -> Result<ShardArtifact> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read shard artifact {path}: {e}")))?;
        let doc = parse_json(&text)
            .map_err(|e| Error::Config(format!("shard artifact {path}: {e}")))?;
        ShardArtifact::from_value(&doc)
            .map_err(|e| Error::Config(format!("shard artifact {path}: {e}")))
    }

    /// Resume probe: `Some(artifact)` iff `path` holds a valid artifact
    /// for exactly this fingerprint and index range — the signal that a
    /// shard finished in an earlier run and can be skipped. Any failure
    /// (missing file, parse error, mismatch) is `None`: the shard is
    /// simply recomputed.
    pub fn load_if_complete(
        path: &str,
        fingerprint: &str,
        range: &Range<usize>,
    ) -> Option<ShardArtifact> {
        let artifact = ShardArtifact::load(path).ok()?;
        (artifact.fingerprint() == fingerprint && artifact.range() == *range).then_some(artifact)
    }
}

/// The result of merging shard artifacts: the combined summary plus
/// coverage accounting (which index ranges are still missing).
#[derive(Clone, Debug)]
pub struct MergedSweep {
    /// The sweep fingerprint all merged shards share.
    pub fingerprint: String,
    /// The sweep spec (from the artifacts).
    pub spec: SweepSpec,
    /// The merged rollup.
    pub summary: SweepSummary,
    /// Grid points covered by the merged shards.
    pub covered: usize,
    /// Total grid points of the sweep.
    pub total: usize,
    /// Index ranges no merged shard covered (empty iff complete).
    pub missing: Vec<Range<usize>>,
}

impl MergedSweep {
    /// Whether every grid point was covered — only then is the summary
    /// comparable to the single-process [`SweepSummary::compute`].
    pub fn is_complete(&self) -> bool {
        self.covered == self.total
    }
}

/// Merge any subset of shard artifacts (in any order). Typed errors for
/// an empty input, mismatched fingerprints (shards of different sweeps),
/// and overlapping index ranges (e.g. shards of the same sweep planned
/// with different shard counts).
pub fn merge_shards(artifacts: &[ShardArtifact]) -> Result<MergedSweep> {
    let first = artifacts
        .first()
        .ok_or_else(|| Error::Config("no shard artifacts to merge".into()))?;
    // Compare the full canonical spec/model/objective strings, not just
    // the 64-bit FNV digest — FNV is not collision-resistant, and
    // merging shards of two different sweeps (including tri-objective
    // shards under different SNR contexts, or mixed with classic
    // power/area shards) must be impossible, not merely unlikely.
    let canonical_of = |a: &ShardArtifact| {
        sweep_canonical_with(&a.spec, &a.model, a.summary.snr_context().as_ref())
    };
    let first_canonical = canonical_of(first);
    for a in &artifacts[1..] {
        if a.fingerprint != first.fingerprint || canonical_of(a) != first_canonical {
            return Err(Error::Config(format!(
                "shard artifact fingerprint mismatch: shard {} has `{}` but shard {} has \
                 `{}` — the artifacts belong to different sweeps (spec or model differs)",
                first.selector, first.fingerprint, a.selector, a.fingerprint
            )));
        }
    }
    // Identical canonical strings imply identical spec/model bits, so
    // `total` agrees across artifacts too.
    let total = first.total;
    let mut occupied: Vec<Range<usize>> = artifacts
        .iter()
        .map(ShardArtifact::range)
        .filter(|r| !r.is_empty())
        .collect();
    occupied.sort_by_key(|r| (r.start, r.end));
    for w in occupied.windows(2) {
        if w[1].start < w[0].end {
            return Err(Error::Config(format!(
                "shard ranges overlap: {:?} and {:?} (merging shards from different \
                 shard counts of the same sweep?)",
                w[0], w[1]
            )));
        }
    }
    let covered = occupied.iter().map(|r| r.len()).sum();
    let mut missing = Vec::new();
    let mut cursor = 0usize;
    for r in &occupied {
        if r.start > cursor {
            missing.push(cursor..r.start);
        }
        cursor = r.end;
    }
    if cursor < total {
        missing.push(cursor..total);
    }
    let summary = artifacts
        .iter()
        .map(|a| a.summary.clone())
        .fold(SweepSummary::new(), SweepSummary::merge);
    Ok(MergedSweep {
        fingerprint: first.fingerprint.clone(),
        spec: first.spec.clone(),
        summary,
        covered,
        total,
        missing,
    })
}

#[cfg(test)]
mod tests {
    use super::super::{sweep_min_eap, sweep_power_area_front};
    use super::*;

    fn small_spec() -> SweepSpec {
        SweepSpec {
            enobs: vec![4.0, 8.0, 12.0],
            total_throughputs: vec![1e6, 1e8, 1e10],
            tech_nms: vec![16.0, 32.0],
            n_adcs: vec![1, 4],
        }
    }

    fn oversized_spec() -> SweepSpec {
        SweepSpec {
            enobs: vec![8.0; 1 << 17],
            total_throughputs: vec![1e9; 1 << 17],
            tech_nms: vec![32.0; 1 << 17],
            n_adcs: vec![1; 1 << 17],
        }
    }

    #[test]
    fn selector_parses_and_rejects() {
        let s = ShardSelector::parse("2/7").unwrap();
        assert_eq!((s.index(), s.n_shards()), (2, 7));
        assert_eq!(s.to_string(), "2/7");
        assert_eq!(ShardSelector::parse(" 0 / 1 ").unwrap().n_shards(), 1);
        for bad in ["0/0", "3/2", "2/2", "junk", "1", "1/", "/3", "-1/3", "1.5/3", "", "1/3/5"] {
            let err = ShardSelector::parse(bad);
            assert!(err.is_err(), "`{bad}` should be rejected");
            assert!(
                matches!(err.unwrap_err(), Error::Config(_)),
                "`{bad}` should be a typed config error"
            );
        }
    }

    #[test]
    fn plan_partitions_exactly() {
        for len in [0usize, 1, 2, 5, 36, 600] {
            let spec = SweepSpec {
                enobs: vec![8.0; len],
                total_throughputs: vec![1e9],
                tech_nms: vec![32.0],
                n_adcs: vec![1],
            };
            for n_shards in [1usize, 2, 3, 7, 13, 64] {
                let plan = ShardPlan::new(&spec, n_shards).unwrap();
                let mut cursor = 0usize;
                let mut sizes = Vec::new();
                for r in plan.ranges() {
                    assert_eq!(r.start, cursor, "len={len} n={n_shards}");
                    cursor = r.end;
                    sizes.push(r.len());
                }
                assert_eq!(cursor, len, "union must cover the grid");
                let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(hi - lo <= 1, "balanced split: {sizes:?}");
            }
        }
    }

    #[test]
    fn plan_rejects_zero_shards_and_overflowed_grids() {
        let spec = small_spec();
        assert!(matches!(ShardPlan::new(&spec, 0), Err(Error::Config(_))));
        assert!(matches!(ShardPlan::new(&oversized_spec(), 4), Err(Error::Numeric(_))));
    }

    #[test]
    fn summary_matches_single_process_rollups() {
        let spec = small_spec();
        let model = AdcModel::default();
        for workers in [1usize, 4] {
            let summary = SweepSummary::compute(&spec, &model, workers);
            assert_eq!(summary.count(), spec.len());
            let expect = sweep_min_eap(&spec, &model, 1).unwrap();
            let got = summary.min_eap().unwrap();
            assert_eq!(got.query, expect.query);
            assert_eq!(got.metrics.to_bits(), expect.metrics.to_bits());
            assert_eq!(summary.front_indices(), sweep_power_area_front(&spec, &model, 1));
            let e = summary.extrema().unwrap();
            for k in 0..4 {
                assert!(e.min[k] <= e.max[k]);
            }
        }
    }

    #[test]
    fn summary_json_roundtrip_is_bit_exact() {
        let spec = small_spec();
        let model = AdcModel::default();
        let summary = SweepSummary::compute(&spec, &model, 4);
        let text = summary.to_json_string().unwrap();
        let back = SweepSummary::from_value(&parse_json(&text).unwrap()).unwrap();
        assert_eq!(back.to_json_string().unwrap(), text);
        // Empty summary too.
        let empty = SweepSummary::new();
        let text = empty.to_json_string().unwrap();
        let back = SweepSummary::from_value(&parse_json(&text).unwrap()).unwrap();
        assert_eq!(back.count(), 0);
        assert!(back.min_eap().is_none() && back.extrema().is_none());
        assert_eq!(back.to_json_string().unwrap(), text);
    }

    #[test]
    fn sharded_merge_reproduces_single_process_bitwise() {
        let spec = small_spec();
        let model = AdcModel::default();
        let reference = SweepSummary::compute(&spec, &model, 4).to_json_string().unwrap();
        for n_shards in [1usize, 3, 5, 36, 50] {
            let mut artifacts: Vec<ShardArtifact> = (0..n_shards)
                .map(|i| {
                    ShardArtifact::compute(
                        &spec,
                        &model,
                        ShardSelector::new(i, n_shards).unwrap(),
                        2,
                    )
                    .unwrap()
                })
                .collect();
            // Merge order must not matter: rotate and reverse.
            artifacts.rotate_left(n_shards / 2);
            artifacts.reverse();
            let merged = merge_shards(&artifacts).unwrap();
            assert!(merged.is_complete(), "n_shards={n_shards}");
            assert!(merged.missing.is_empty());
            assert_eq!(
                merged.summary.to_json_string().unwrap(),
                reference,
                "n_shards={n_shards}"
            );
        }
    }

    #[test]
    fn empty_and_single_point_shards_merge_cleanly() {
        // 50 shards over 36 points: 14 shards are empty, the rest single
        // or double points — and an entirely empty grid.
        let empty = SweepSpec { enobs: vec![], ..small_spec() };
        let model = AdcModel::default();
        for spec in [small_spec(), empty] {
            let n_shards = 50usize;
            let artifacts: Vec<ShardArtifact> = (0..n_shards)
                .map(|i| {
                    ShardArtifact::compute(
                        &spec,
                        &model,
                        ShardSelector::new(i, n_shards).unwrap(),
                        1,
                    )
                    .unwrap()
                })
                .collect();
            let merged = merge_shards(&artifacts).unwrap();
            assert!(merged.is_complete());
            assert_eq!(merged.summary.count(), spec.len());
            assert_eq!(
                merged.summary.to_json_string().unwrap(),
                SweepSummary::compute(&spec, &model, 1).to_json_string().unwrap()
            );
        }
    }

    #[test]
    fn artifact_json_roundtrip_and_resume_probe() {
        let spec = small_spec();
        let model = AdcModel::default();
        let artifact =
            ShardArtifact::compute(&spec, &model, ShardSelector::new(1, 3).unwrap(), 2).unwrap();
        let text = artifact.to_json_string().unwrap();
        let back = ShardArtifact::from_value(&parse_json(&text).unwrap()).unwrap();
        assert_eq!(back.fingerprint(), artifact.fingerprint());
        assert_eq!(back.range(), artifact.range());
        assert_eq!(back.to_json_string().unwrap(), text);

        let path = std::env::temp_dir()
            .join(format!("cimdse_shard_unit_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        artifact.write(&path).unwrap();
        let fp = sweep_fingerprint(&spec, &model);
        assert!(ShardArtifact::load_if_complete(&path, &fp, &artifact.range()).is_some());
        // Wrong fingerprint or range: not a resume hit.
        assert!(ShardArtifact::load_if_complete(&path, "0000000000000000", &artifact.range())
            .is_none());
        assert!(ShardArtifact::load_if_complete(&path, &fp, &(0..1)).is_none());
        // Corrupt file: typed error from load, None from the probe.
        std::fs::write(&path, "{ not json").unwrap();
        assert!(matches!(ShardArtifact::load(&path), Err(Error::Config(_))));
        assert!(ShardArtifact::load_if_complete(&path, &fp, &artifact.range()).is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn merge_rejects_mixed_and_overlapping_artifacts() {
        let spec = small_spec();
        let model = AdcModel::default();
        let tuned = AdcModel { energy_offset_decades: 0.1, ..model };
        let a = ShardArtifact::compute(&spec, &model, ShardSelector::new(0, 2).unwrap(), 1)
            .unwrap();
        let b = ShardArtifact::compute(&spec, &tuned, ShardSelector::new(1, 2).unwrap(), 1)
            .unwrap();
        assert_ne!(a.fingerprint(), b.fingerprint());
        let err = merge_shards(&[a.clone(), b]).unwrap_err().to_string();
        assert!(err.contains("fingerprint"), "{err}");

        // Same sweep, different shard counts: ranges overlap.
        let whole = ShardArtifact::compute(&spec, &model, ShardSelector::new(0, 1).unwrap(), 1)
            .unwrap();
        let err = merge_shards(&[a.clone(), whole]).unwrap_err().to_string();
        assert!(err.contains("overlap"), "{err}");

        assert!(merge_shards(&[]).is_err());

        // A valid subset merges but reports what is missing.
        let merged = merge_shards(&[a]).unwrap();
        assert!(!merged.is_complete());
        assert_eq!(merged.covered + merged.missing.iter().map(|r| r.len()).sum::<usize>(), 36);
        assert_eq!(merged.missing, vec![18..36]);
    }

    #[test]
    fn from_value_rejects_tampered_artifacts() {
        let spec = small_spec();
        let model = AdcModel::default();
        let artifact =
            ShardArtifact::compute(&spec, &model, ShardSelector::new(0, 2).unwrap(), 1).unwrap();
        let good = artifact.to_json_string().unwrap();
        // Stored fingerprint that disagrees with the embedded spec/model.
        let tampered = good.replace(&artifact.fingerprint().to_string(), "deadbeefdeadbeef");
        let err = ShardArtifact::from_value(&parse_json(&tampered).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("fingerprint"), "{err}");
        // Wrong kind.
        let err = ShardArtifact::from_value(&parse_json("{\"kind\": \"x\"}").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("kind"), "{err}");

        // A single flipped payload hex digit (here: the energy extremum)
        // trips the summary checksum.
        let hex = f64_to_bits_hex(artifact.summary().extrema().unwrap().min[0]);
        let mut flipped: Vec<char> = hex.chars().collect();
        flipped[15] = if flipped[15] == '0' { '1' } else { '0' };
        let flipped: String = flipped.into_iter().collect();
        let tampered = good.replacen(&hex, &flipped, 1);
        assert_ne!(tampered, good, "the tamper must actually change the payload");
        let err = ShardArtifact::from_value(&parse_json(&tampered).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("checksum"), "{err}");

        // Nulling the payload while keeping the count is caught too.
        let parsed = parse_json(&good).unwrap();
        let orig_count = parsed.get("summary.count").cloned().unwrap();
        let mut root = match parsed {
            Value::Table(map) => map,
            _ => unreachable!("artifacts are tables"),
        };
        let mut doctored = match SweepSummary::new().to_value() {
            Value::Table(map) => map,
            _ => unreachable!("summaries are tables"),
        };
        doctored.insert("count".into(), orig_count);
        root.insert("summary".into(), Value::Table(doctored));
        let err = ShardArtifact::from_value(&Value::Table(root)).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn model_fingerprint_tracks_model_bits_only() {
        let model = AdcModel::default();
        let base = model_fingerprint(&model);
        assert_eq!(base.len(), 16);
        assert_eq!(base, model_fingerprint(&model.clone()));
        let tuned = AdcModel { energy_offset_decades: 1e-300, ..model };
        assert_ne!(base, model_fingerprint(&tuned));
        let mut coefs = model.coefs;
        coefs.a0 += 1e-12;
        assert_ne!(base, model_fingerprint(&AdcModel { coefs, ..model }));
        // Round-tripping the model through its canonical value keeps the
        // fingerprint (the cache key survives the wire).
        let back = model_from_value(&model_to_value(&model)).unwrap();
        assert_eq!(base, model_fingerprint(&back));
    }

    #[test]
    fn classic_payload_bytes_do_not_change_without_snr() {
        // The SNR objective is strictly additive: without it, summaries
        // serialize without any `snr_front` key and the canonical
        // fingerprint is the pre-existing one.
        let spec = small_spec();
        let model = AdcModel::default();
        let summary = SweepSummary::compute(&spec, &model, 2);
        let text = summary.to_json_string().unwrap();
        assert!(!text.contains("snr_front"), "{text}");
        assert!(summary.snr_context().is_none() && summary.snr_front().is_none());
        assert_eq!(
            sweep_fingerprint(&spec, &model),
            sweep_fingerprint_with(&spec, &model, None)
        );
        let ctx = crate::dse::SnrContext::default();
        assert_ne!(
            sweep_fingerprint(&spec, &model),
            sweep_fingerprint_with(&spec, &model, Some(&ctx))
        );
        // Different contexts => different fingerprints.
        let other = crate::dse::SnrContext { n_sum: 128, ..ctx };
        assert_ne!(
            sweep_fingerprint_with(&spec, &model, Some(&ctx)),
            sweep_fingerprint_with(&spec, &model, Some(&other))
        );
    }

    #[test]
    fn tri_objective_summary_roundtrips_and_matches_library_front() {
        let spec = small_spec();
        let model = AdcModel::default();
        let ctx = crate::dse::SnrContext::default();
        let summary = SweepSummary::compute_with(&spec, &model, 4, Some(ctx));
        assert_eq!(summary.snr_context(), Some(ctx));
        let indices = summary.snr_front_indices().unwrap();
        assert!(!indices.is_empty());
        assert_eq!(
            indices,
            super::super::sweep_energy_area_snr_front(&spec, &model, 1, &ctx).into_indices()
        );
        // The classic power/area components are untouched by the extra
        // objective.
        assert_eq!(summary.front_indices(), sweep_power_area_front(&spec, &model, 1));
        // Bit-exact JSON round-trip, snr payload included.
        let text = summary.to_json_string().unwrap();
        assert!(text.contains("snr_front"), "{text}");
        let back = SweepSummary::from_value(&parse_json(&text).unwrap()).unwrap();
        assert_eq!(back.to_json_string().unwrap(), text);
    }

    #[test]
    fn tri_objective_sharded_merge_reproduces_single_process_bitwise() {
        let spec = small_spec();
        let model = AdcModel::default();
        let ctx = crate::dse::SnrContext { n_sum: 2048, cell_bits: 2 };
        let reference =
            SweepSummary::compute_with(&spec, &model, 4, Some(ctx)).to_json_string().unwrap();
        for n_shards in [1usize, 3, 7] {
            let mut artifacts: Vec<ShardArtifact> = (0..n_shards)
                .map(|i| {
                    ShardArtifact::compute_with(
                        &spec,
                        &model,
                        ShardSelector::new(i, n_shards).unwrap(),
                        2,
                        Some(ctx),
                    )
                    .unwrap()
                })
                .collect();
            artifacts.reverse();
            // Artifacts survive serialization before merging (the real
            // multi-process path).
            let artifacts: Vec<ShardArtifact> = artifacts
                .iter()
                .map(|a| {
                    ShardArtifact::from_value(&parse_json(&a.to_json_string().unwrap()).unwrap())
                        .unwrap()
                })
                .collect();
            let merged = merge_shards(&artifacts).unwrap();
            assert!(merged.is_complete(), "n_shards={n_shards}");
            assert_eq!(
                merged.summary.to_json_string().unwrap(),
                reference,
                "n_shards={n_shards}"
            );
        }
    }

    #[test]
    fn merge_rejects_mixed_objective_sets_and_contexts() {
        let spec = small_spec();
        let model = AdcModel::default();
        let ctx = crate::dse::SnrContext::default();
        let classic =
            ShardArtifact::compute(&spec, &model, ShardSelector::new(0, 2).unwrap(), 1).unwrap();
        let tri = ShardArtifact::compute_with(
            &spec,
            &model,
            ShardSelector::new(1, 2).unwrap(),
            1,
            Some(ctx),
        )
        .unwrap();
        assert_ne!(classic.fingerprint(), tri.fingerprint());
        let err = merge_shards(&[classic, tri.clone()]).unwrap_err().to_string();
        assert!(err.contains("fingerprint"), "{err}");
        let other = ShardArtifact::compute_with(
            &spec,
            &model,
            ShardSelector::new(0, 2).unwrap(),
            1,
            Some(crate::dse::SnrContext { n_sum: 64, cell_bits: 4 }),
        )
        .unwrap();
        let err = merge_shards(&[other, tri]).unwrap_err().to_string();
        assert!(err.contains("fingerprint"), "{err}");
        // An invalid context is a typed error up front.
        assert!(ShardArtifact::compute_with(
            &spec,
            &model,
            ShardSelector::new(0, 2).unwrap(),
            1,
            Some(crate::dse::SnrContext { n_sum: 0, cell_bits: 2 }),
        )
        .is_err());
    }

    #[test]
    fn from_value_rejects_inconsistent_snr_payloads() {
        // count == 0 with a non-empty tri front is structurally bogus.
        let mut map = match SweepSummary::new().to_value() {
            Value::Table(map) => map,
            _ => unreachable!("summaries are tables"),
        };
        let mut front = FrontK::<3>::new();
        front.push([1.0, 2.0, 3.0], 0);
        let mut snr = BTreeMap::new();
        snr.insert("context".to_string(), crate::dse::SnrContext::default().to_value());
        snr.insert("front".to_string(), front.to_value());
        map.insert("snr_front".to_string(), Value::Table(snr.clone()));
        let err =
            SweepSummary::from_value(&Value::Table(map.clone())).unwrap_err().to_string();
        assert!(err.contains("0 points"), "{err}");
        // A context-less or front-less snr payload is rejected too.
        for missing in ["context", "front"] {
            let mut broken = snr.clone();
            broken.remove(missing);
            map.insert("snr_front".to_string(), Value::Table(broken));
            let err = SweepSummary::from_value(&Value::Table(map.clone()))
                .unwrap_err()
                .to_string();
            assert!(err.contains(missing), "{err}");
        }

        // A tri artifact whose snr front cites an index outside the
        // shard's range is rejected (mirror of the power/area check).
        let spec = small_spec();
        let model = AdcModel::default();
        let ctx = crate::dse::SnrContext::default();
        let artifact = ShardArtifact::compute_with(
            &spec,
            &model,
            ShardSelector::new(0, 2).unwrap(),
            1,
            Some(ctx),
        )
        .unwrap();
        let mut doctored = artifact.clone();
        let mut front = FrontK::<3>::new();
        // Index 20 lies in shard 1's half of the 36-point grid.
        front.push([1.0, 2.0, 3.0], 20);
        doctored.summary.snr = Some((ctx, front));
        // to_value recomputes the (now consistent) checksum, so only the
        // range validation can catch the out-of-shard index.
        let err = ShardArtifact::from_value(&doctored.to_value()).unwrap_err().to_string();
        assert!(err.contains("snr front index 20"), "{err}");
    }

    #[test]
    fn fingerprint_tracks_every_input_bit() {
        let spec = small_spec();
        let model = AdcModel::default();
        let base = sweep_fingerprint(&spec, &model);
        assert_eq!(base.len(), 16);
        let mut spec2 = spec.clone();
        spec2.enobs[0] = 4.000000000000001;
        assert_ne!(base, sweep_fingerprint(&spec2, &model));
        let mut spec3 = spec.clone();
        spec3.n_adcs[0] = 2;
        assert_ne!(base, sweep_fingerprint(&spec3, &model));
        let tuned = AdcModel { area_offset_decades: 1e-300, ..model };
        assert_ne!(base, sweep_fingerprint(&spec, &tuned));
        assert_eq!(base, sweep_fingerprint(&spec.clone(), &model));
    }
}
