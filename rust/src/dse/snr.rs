//! Compute-SNR metric for analog in-memory-computing sweeps.
//!
//! The source paper models ADC energy/area from architecture-level
//! attributes; the follow-on literature ("Compute SNR-Optimal
//! Analog-to-Digital Converters for Analog In-Memory Computing",
//! Kavishwar & Shanbhag 2025 — see PAPERS.md) optimizes the same ADCs
//! for *compute SNR*: the end-to-end fidelity of the analog dot-product
//! read through a finite-resolution converter. This module provides
//! that metric from the same architecture-level attributes the rest of
//! the crate uses — the analog sum size `n_sum`, the per-cell bit width
//! `cell_bits`, and the ADC's ENOB — so tri-objective
//! (energy, area, SNR) sweeps need no circuit-level inputs.
//!
//! Two independent noise sources are combined (noise powers add,
//! [`combine_sndr_db`]):
//!
//! 1. **Quantization** — reading a column sum that needs
//!    [`lossless_bits`] through an `enob`-bit quantizer yields
//!    [`expected_read_sqnr_db`]: `6.02·min(enob, lossless) + 1.76` dB.
//! 2. **Clipping** — when the ADC is short of lossless
//!    ([`clipped_bits`] > 0), the unrecovered range contributes
//!    square-law distortion at 12.04 dB (two ENOB-equivalents) per
//!    clipped bit below the lossless ceiling:
//!    `ideal_sndr_db(lossless) − 12.04·clipped`. An over-provisioned
//!    ADC clips nothing and the term is the `+∞` dB identity.
//!
//! The derivation, its assumptions, and worked RAELLA S/M/L/XL numbers
//! live in `rust/docs/snr_metric.md`; golden anchors are pinned in
//! `tests/golden_figures.json`.

use crate::adc::enob::{
    clipped_bits, combine_sndr_db, expected_read_sqnr_db, ideal_sndr_db, lossless_bits,
};
use crate::config::Value;
use crate::error::{Error, Result};

/// SNDR (dB) of the clipping/saturation distortion alone: the square-law
/// penalty of reading a [`lossless_bits`]-bit sum with an ADC that is
/// [`clipped_bits`] short of it. `+∞` dB (no distortion) when nothing
/// clips, so it is the identity under [`combine_sndr_db`].
pub fn clipping_sndr_db(n_sum: usize, cell_bits: u32, adc_bits: f64) -> f64 {
    let clipped = clipped_bits(n_sum, cell_bits, adc_bits);
    if clipped == f64::INFINITY {
        // A saturated level count (`cell_bits >= 1024`, see
        // `adc::enob::pow2_f64`) clips infinitely: infinite distortion,
        // not the `∞ − ∞ = NaN` the raw formula would produce.
        f64::NEG_INFINITY
    } else if clipped > 0.0 {
        ideal_sndr_db(lossless_bits(n_sum, cell_bits)) - 2.0 * 6.02 * clipped
    } else {
        f64::INFINITY
    }
}

/// Compute SNR (dB) of an analog dot-product of `n_sum` values stored in
/// `cell_bits`-bit cells, read through an ADC with effective resolution
/// `enob`: quantization SQNR and clipping distortion combined as
/// independent noise powers. Total on any input (NaN propagates; see
/// [`combine_sndr_db`]).
pub fn compute_snr_db(n_sum: usize, cell_bits: u32, enob: f64) -> f64 {
    combine_sndr_db(&[
        expected_read_sqnr_db(n_sum, cell_bits, enob),
        clipping_sndr_db(n_sum, cell_bits, enob),
    ])
}

/// Architecture context the compute-SNR objective needs beyond the ADC's
/// ENOB (which the sweep grid already carries): the analog sum size and
/// per-cell bit width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SnrContext {
    /// Values summed on a column line per ADC convert.
    pub n_sum: usize,
    /// Bits stored per memory cell.
    pub cell_bits: u32,
}

impl Default for SnrContext {
    /// RAELLA-M: 512-element sums of 2-bit cells (`arch::raella`).
    fn default() -> Self {
        SnrContext { n_sum: 512, cell_bits: 2 }
    }
}

impl SnrContext {
    /// [`compute_snr_db`] for this context at the given ENOB.
    pub fn compute_snr_db(&self, enob: f64) -> f64 {
        compute_snr_db(self.n_sum, self.cell_bits, enob)
    }

    /// Validate the context: both attributes must be positive (the math
    /// is total regardless, but a zero sum or zero-bit cell is a caller
    /// bug, not a design point).
    pub fn validate(&self) -> Result<()> {
        if self.n_sum == 0 {
            return Err(Error::Config("snr context: n_sum must be >= 1".into()));
        }
        if self.cell_bits == 0 {
            return Err(Error::Config("snr context: cell_bits must be >= 1".into()));
        }
        Ok(())
    }

    /// Serialize as a canonical `{"cell_bits": B, "n_sum": N}` table.
    pub fn to_value(&self) -> Value {
        let mut map = std::collections::BTreeMap::new();
        map.insert("n_sum".to_string(), Value::Number(self.n_sum as f64));
        map.insert("cell_bits".to_string(), Value::Number(self.cell_bits as f64));
        Value::Table(map)
    }

    /// Inverse of [`SnrContext::to_value`], with typed errors on missing
    /// or mistyped fields and validation applied.
    pub fn from_value(v: &Value) -> Result<SnrContext> {
        let Value::Table(table) = v else {
            return Err(Error::Config("snr context is not a table".into()));
        };
        for key in table.keys() {
            if key != "n_sum" && key != "cell_bits" {
                return Err(Error::Config(format!("snr context: unknown key `{key}`")));
            }
        }
        let n_sum = v
            .get("n_sum")
            .and_then(Value::as_usize)
            .ok_or_else(|| {
                Error::Config("snr context: `n_sum` missing or not a non-negative integer".into())
            })?;
        let cell_bits = v
            .get("cell_bits")
            .and_then(Value::as_usize)
            .filter(|&b| b <= u32::MAX as usize)
            .ok_or_else(|| {
                Error::Config("snr context: `cell_bits` missing or not a u32 integer".into())
            })?;
        let ctx = SnrContext { n_sum, cell_bits: cell_bits as u32 };
        ctx.validate()?;
        Ok(ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::raella::{RaellaVariant, raella};

    #[test]
    fn over_provisioned_adc_reaches_the_lossless_ceiling() {
        // ENOB >= lossless bits: no clipping, SNR == ideal SQNR of the
        // lossless read, bit-for-bit (the clipping term is the identity).
        let (n_sum, cell_bits) = (16usize, 2u32);
        let lossless = lossless_bits(n_sum, cell_bits);
        let snr = compute_snr_db(n_sum, cell_bits, 12.0);
        assert_eq!(snr.to_bits(), ideal_sndr_db(lossless).to_bits());
        assert_eq!(clipping_sndr_db(n_sum, cell_bits, 12.0), f64::INFINITY);
    }

    #[test]
    fn snr_is_monotone_in_enob_and_saturates() {
        let ctx = SnrContext::default();
        let mut prev = f64::NEG_INFINITY;
        for enob in [3.0, 5.0, 7.0, 9.0, 11.0] {
            let snr = ctx.compute_snr_db(enob);
            assert!(snr.is_finite());
            assert!(snr > prev, "enob={enob}: {snr} <= {prev}");
            prev = snr;
        }
        // Beyond lossless, extra ENOB buys nothing.
        let ceiling = ideal_sndr_db(lossless_bits(ctx.n_sum, ctx.cell_bits));
        assert!(ctx.compute_snr_db(14.0) <= ceiling + 1e-12);
        assert!(ctx.compute_snr_db(20.0).to_bits() == ctx.compute_snr_db(23.0).to_bits());
    }

    #[test]
    fn clipping_dominates_underprovisioned_reads() {
        // RAELLA-style operation sits well below lossless: the combined
        // SNR must land below both the quantization-only figure and the
        // clipping-only figure (noise powers add).
        for v in RaellaVariant::ALL {
            let a = raella(v);
            let snr = compute_snr_db(a.sum_size, a.cell_bits, a.adc.enob);
            let q = expected_read_sqnr_db(a.sum_size, a.cell_bits, a.adc.enob);
            let c = clipping_sndr_db(a.sum_size, a.cell_bits, a.adc.enob);
            assert!(snr < q && snr < c, "{v:?}: snr={snr} q={q} c={c}");
            assert!(snr > 0.0, "{v:?}: {snr}");
        }
    }

    #[test]
    fn metric_is_total_on_degenerate_inputs() {
        // Huge cell widths saturate (see `adc::enob::pow2_f64`) instead
        // of panicking; an infinitely-clipped read is -inf dB (infinite
        // distortion); NaN ENOB propagates instead of asserting.
        assert!(compute_snr_db(128, 64, 6.0).is_finite());
        assert_eq!(compute_snr_db(128, 5000, 6.0), f64::NEG_INFINITY);
        assert!(compute_snr_db(512, 2, f64::NAN).is_nan());
    }

    #[test]
    fn context_value_roundtrip_and_rejections() {
        use crate::config::parse_json;
        let ctx = SnrContext { n_sum: 2048, cell_bits: 3 };
        let text = ctx.to_value().to_json_string().unwrap();
        assert_eq!(SnrContext::from_value(&parse_json(&text).unwrap()).unwrap(), ctx);
        assert_eq!(
            SnrContext::from_value(&SnrContext::default().to_value()).unwrap(),
            SnrContext::default()
        );
        for text in [
            "[]",
            "{}",
            "{\"n_sum\": 512}",
            "{\"n_sum\": 512, \"cell_bits\": 2, \"extra\": 1}",
            "{\"n_sum\": 0, \"cell_bits\": 2}",
            "{\"n_sum\": 512, \"cell_bits\": 0}",
            "{\"n_sum\": 1.5, \"cell_bits\": 2}",
            "{\"n_sum\": 512, \"cell_bits\": 5000000000}",
        ] {
            let v = parse_json(text).unwrap();
            assert!(SnrContext::from_value(&v).is_err(), "{text}");
        }
    }
}
