//! Sweep specification: cartesian grids over the model's four inputs.

use crate::adc::AdcQuery;
use crate::util::logspace::logspace;

/// A cartesian sweep over (ENOB, total throughput, tech node, #ADCs).
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// ENOB values.
    pub enobs: Vec<f64>,
    /// Aggregate throughputs (converts/s).
    pub total_throughputs: Vec<f64>,
    /// Technology nodes (nm).
    pub tech_nms: Vec<f64>,
    /// Parallel ADC counts.
    pub n_adcs: Vec<u32>,
}

impl SweepSpec {
    /// The paper's Fig. 5 exploration grid: 1..16 ADCs, total throughput
    /// 1.3e9..40e9 converts/s, at 32 nm for the given ENOB.
    pub fn fig5(enob: f64, throughput_steps: usize) -> SweepSpec {
        SweepSpec {
            enobs: vec![enob],
            total_throughputs: logspace(1.3e9, 40e9, throughput_steps),
            tech_nms: vec![32.0],
            n_adcs: vec![1, 2, 4, 8, 16],
        }
    }

    /// A dense interpolation grid (the capability prior work lacked):
    /// ENOB 2..14, throughput 1e4..1e10, across common nodes.
    pub fn dense(points_per_axis: usize) -> SweepSpec {
        SweepSpec {
            enobs: crate::util::logspace::linspace(2.0, 14.0, points_per_axis),
            total_throughputs: logspace(1e4, 1e10, points_per_axis),
            tech_nms: vec![16.0, 32.0, 65.0, 130.0],
            n_adcs: vec![1, 2, 4, 8, 16, 32],
        }
    }

    /// Number of design points in the grid.
    pub fn len(&self) -> usize {
        self.enobs.len() * self.total_throughputs.len() * self.tech_nms.len() * self.n_adcs.len()
    }

    /// Whether the grid is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize the cartesian product (ENOB-major, n_adcs-minor order).
    pub fn points(&self) -> Vec<AdcQuery> {
        let mut out = Vec::with_capacity(self.len());
        for &enob in &self.enobs {
            for &total_throughput in &self.total_throughputs {
                for &tech_nm in &self.tech_nms {
                    for &n_adcs in &self.n_adcs {
                        out.push(AdcQuery { enob, total_throughput, tech_nm, n_adcs });
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cartesian_count_and_order() {
        let s = SweepSpec {
            enobs: vec![4.0, 8.0],
            total_throughputs: vec![1e8, 1e9],
            tech_nms: vec![32.0],
            n_adcs: vec![1, 2],
        };
        let pts = s.points();
        assert_eq!(pts.len(), s.len());
        assert_eq!(pts.len(), 8);
        assert_eq!(pts[0].n_adcs, 1);
        assert_eq!(pts[1].n_adcs, 2);
        assert_eq!(pts[0].enob, 4.0);
        assert_eq!(pts[7].enob, 8.0);
    }

    #[test]
    fn fig5_grid_matches_paper_ranges() {
        let s = SweepSpec::fig5(7.0, 5);
        assert_eq!(s.n_adcs, vec![1, 2, 4, 8, 16]);
        assert!((s.total_throughputs[0] - 1.3e9).abs() / 1.3e9 < 1e-9);
        assert!((s.total_throughputs[4] - 40e9).abs() / 40e9 < 1e-9);
        assert_eq!(s.len(), 25);
    }

    #[test]
    fn dense_grid_is_dense() {
        let s = SweepSpec::dense(10);
        assert_eq!(s.len(), 10 * 10 * 4 * 6);
    }
}
