//! Sweep specification: cartesian grids over the model's four inputs.
//!
//! Grids can be *materialized* ([`SweepSpec::points`]) or — for the
//! million-point exploration the streaming engine targets — accessed by
//! index ([`SweepSpec::point_at`]) and generated per chunk
//! ([`SweepSpec::fill_range`], [`SweepSpec::chunks`]) so no full query
//! vector ever exists in memory.

use std::ops::Range;

use crate::adc::AdcQuery;
use crate::config::Value;
use crate::error::{Error, Result};
use crate::util::logspace::{log10, logspace};

/// Numeric tier a sweep evaluates on (see `rust/docs/numeric_tiers.md`).
///
/// [`SweepTier::Exact`] is the libm-backed bit-exact reference — the
/// only tier fingerprinted or golden-pinned outputs (shard artifacts,
/// served responses, sweep summaries, golden figures) ever run on.
/// [`SweepTier::Fast`] is the opt-in lane-batched polynomial tier
/// (`util::fastmath` + `PreparedRowLanes`): same results to within a
/// property-tested ULP bound, roughly the same on every host (the
/// AVX2 and portable backends are bit-identical to each other).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SweepTier {
    /// Bit-exact libm-backed scalar reference (the default).
    #[default]
    Exact,
    /// ULP-bounded lane-batched polynomial tier.
    Fast,
}

impl SweepTier {
    /// Parse a CLI/user-supplied tier name; typed error names the set.
    pub fn parse(s: &str) -> Result<SweepTier> {
        match s {
            "exact" => Ok(SweepTier::Exact),
            "fast" => Ok(SweepTier::Fast),
            other => Err(Error::Config(format!(
                "unknown sweep tier `{other}` (valid tiers: fast, exact)"
            ))),
        }
    }

    /// The stable lower-case name (`"exact"` / `"fast"`).
    pub fn name(self) -> &'static str {
        match self {
            SweepTier::Exact => "exact",
            SweepTier::Fast => "fast",
        }
    }
}

/// A cartesian sweep over (ENOB, total throughput, tech node, #ADCs).
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// ENOB values.
    pub enobs: Vec<f64>,
    /// Aggregate throughputs (converts/s).
    pub total_throughputs: Vec<f64>,
    /// Technology nodes (nm).
    pub tech_nms: Vec<f64>,
    /// Parallel ADC counts.
    pub n_adcs: Vec<u32>,
}

impl SweepSpec {
    /// The paper's Fig. 5 exploration grid: 1..16 ADCs, total throughput
    /// 1.3e9..40e9 converts/s, at 32 nm for the given ENOB.
    pub fn fig5(enob: f64, throughput_steps: usize) -> SweepSpec {
        SweepSpec {
            enobs: vec![enob],
            total_throughputs: logspace(1.3e9, 40e9, throughput_steps),
            tech_nms: vec![32.0],
            n_adcs: vec![1, 2, 4, 8, 16],
        }
    }

    /// A dense interpolation grid (the capability prior work lacked):
    /// ENOB 2..14, throughput 1e4..1e10, across common nodes.
    pub fn dense(points_per_axis: usize) -> SweepSpec {
        SweepSpec {
            enobs: crate::util::logspace::linspace(2.0, 14.0, points_per_axis),
            total_throughputs: logspace(1e4, 1e10, points_per_axis),
            tech_nms: vec![16.0, 32.0, 65.0, 130.0],
            n_adcs: vec![1, 2, 4, 8, 16, 32],
        }
    }

    /// Number of design points in the grid, if it fits a `usize`.
    /// `None` means the axis product overflowed — such a grid can still
    /// be described, but not indexed or materialized.
    pub fn checked_len(&self) -> Option<usize> {
        self.enobs
            .len()
            .checked_mul(self.total_throughputs.len())?
            .checked_mul(self.tech_nms.len())?
            .checked_mul(self.n_adcs.len())
    }

    /// Number of design points in the grid, saturating at `usize::MAX`
    /// when the axis product overflows (debug and release builds agree;
    /// use [`SweepSpec::checked_len`] to detect the cap).
    pub fn len(&self) -> usize {
        self.checked_len().unwrap_or(usize::MAX)
    }

    /// Whether the grid is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `i`-th design point in ENOB-major, n_adcs-minor order — the
    /// same order [`SweepSpec::points`] materializes. Panics if `i` is
    /// out of bounds (including a length-overflowed grid).
    pub fn point_at(&self, i: usize) -> AdcQuery {
        let n = self.n_adcs.len();
        let k = self.tech_nms.len();
        let t = self.total_throughputs.len();
        assert!(
            i < self.checked_len().expect("sweep grid length overflows usize"),
            "point index {i} out of bounds"
        );
        AdcQuery {
            enob: self.enobs[i / (n * k * t)],
            total_throughput: self.total_throughputs[(i / (n * k)) % t],
            tech_nm: self.tech_nms[(i / n) % k],
            n_adcs: self.n_adcs[i % n],
        }
    }

    /// Drive `f(i, ei, ti, ki, ni)` over a contiguous index range in
    /// grid order, handing out the decomposed axis indices (ENOB,
    /// throughput, tech, n_adcs). The start index is decomposed once and
    /// the counters tick odometer-style — no per-point div/mod — which
    /// is the single implementation behind both query materialization
    /// ([`SweepSpec::fill_range`]) and the prepared-kernel sweep, so the
    /// two paths cannot drift apart. The range must lie within
    /// `0..len()`.
    pub fn for_each_index_in_range<F>(&self, range: Range<usize>, mut f: F)
    where
        F: FnMut(usize, usize, usize, usize, usize),
    {
        if range.is_empty() {
            return;
        }
        let len = self.checked_len().expect("sweep grid length overflows usize");
        assert!(range.end <= len, "range {range:?} out of bounds for {len} points");
        let n = self.n_adcs.len();
        let k = self.tech_nms.len();
        let t = self.total_throughputs.len();
        let mut ni = range.start % n;
        let mut ki = (range.start / n) % k;
        let mut ti = (range.start / (n * k)) % t;
        let mut ei = range.start / (n * k * t);
        for i in range {
            f(i, ei, ti, ki, ni);
            ni += 1;
            if ni == n {
                ni = 0;
                ki += 1;
                if ki == k {
                    ki = 0;
                    ti += 1;
                    if ti == t {
                        ti = 0;
                        ei += 1;
                    }
                }
            }
        }
    }

    /// Append the queries for a contiguous index range onto `out`. The
    /// range must lie within `0..len()`.
    pub fn fill_range(&self, range: Range<usize>, out: &mut Vec<AdcQuery>) {
        out.reserve(range.len());
        self.for_each_index_in_range(range, |_, ei, ti, ki, ni| {
            out.push(AdcQuery {
                enob: self.enobs[ei],
                total_throughput: self.total_throughputs[ti],
                tech_nm: self.tech_nms[ki],
                n_adcs: self.n_adcs[ni],
            });
        });
    }

    /// Iterate the grid as `(start_index, Vec<AdcQuery>)` chunks of up to
    /// `chunk` points, in order, generating each chunk on demand — the
    /// streaming complement of [`SweepSpec::points`].
    pub fn chunks(&self, chunk: usize) -> impl Iterator<Item = (usize, Vec<AdcQuery>)> + '_ {
        assert!(chunk >= 1);
        let len = self.checked_len().expect("sweep grid length overflows usize");
        (0..len).step_by(chunk).map(move |start| {
            let end = (start + chunk).min(len);
            let mut buf = Vec::new();
            self.fill_range(start..end, &mut buf);
            (start, buf)
        })
    }

    /// The log10 *per-ADC* throughput table the prepared kernel indexes
    /// as `table[ti * n_adcs.len() + ni]`: exactly the
    /// `log10(total/n)` bits [`crate::adc::AdcModel::eval`] derives per
    /// point, computed once per (throughput, n_adcs) pair instead of once
    /// per grid point (the inner loop never calls `log10` again).
    pub fn log_per_adc_table(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.total_throughputs.len() * self.n_adcs.len());
        for &total in &self.total_throughputs {
            for &n in &self.n_adcs {
                out.push(log10(total / n as f64));
            }
        }
        out
    }

    /// Serialize the four axes as a config [`Value`] table. Finite f64
    /// axis values round-trip bit-exactly through the JSON layer (Rust's
    /// `Display` prints the shortest decimal that parses back to the
    /// identical bits); non-finite axis values are rejected by
    /// [`Value::to_json_string`] downstream, matching
    /// [`crate::adc::AdcQuery::validate`]'s view that they are caller
    /// bugs.
    pub fn to_value(&self) -> Value {
        let axis = |xs: &[f64]| Value::Array(xs.iter().map(|&x| Value::Number(x)).collect());
        let mut map = std::collections::BTreeMap::new();
        map.insert("enobs".to_string(), axis(&self.enobs));
        map.insert("total_throughputs".to_string(), axis(&self.total_throughputs));
        map.insert("tech_nms".to_string(), axis(&self.tech_nms));
        map.insert(
            "n_adcs".to_string(),
            Value::Array(self.n_adcs.iter().map(|&n| Value::Number(n as f64)).collect()),
        );
        Value::Table(map)
    }

    /// Inverse of [`SweepSpec::to_value`], with typed errors on missing
    /// or mistyped axes.
    pub fn from_value(v: &Value) -> Result<SweepSpec> {
        fn f64_axis(v: &Value, key: &str) -> Result<Vec<f64>> {
            let arr = v
                .get(key)
                .and_then(Value::as_array)
                .ok_or_else(|| Error::Config(format!("spec axis `{key}` missing or not an array")))?;
            arr.iter()
                .enumerate()
                .map(|(i, item)| {
                    item.as_f64().ok_or_else(|| {
                        Error::Config(format!("spec axis `{key}[{i}]` is not a number"))
                    })
                })
                .collect()
        }
        let n_adcs_vals = v
            .get("n_adcs")
            .and_then(Value::as_array)
            .ok_or_else(|| Error::Config("spec axis `n_adcs` missing or not an array".into()))?;
        let n_adcs = n_adcs_vals
            .iter()
            .enumerate()
            .map(|(i, item)| {
                item.as_usize()
                    .filter(|&n| n <= u32::MAX as usize)
                    .map(|n| n as u32)
                    .ok_or_else(|| {
                        Error::Config(format!("spec axis `n_adcs[{i}]` is not a u32 integer"))
                    })
            })
            .collect::<Result<Vec<u32>>>()?;
        Ok(SweepSpec {
            enobs: f64_axis(v, "enobs")?,
            total_throughputs: f64_axis(v, "total_throughputs")?,
            tech_nms: f64_axis(v, "tech_nms")?,
            n_adcs,
        })
    }

    /// Materialize the cartesian product (ENOB-major, n_adcs-minor order).
    /// Panics (with a streaming hint) if the grid length overflows; use
    /// [`SweepSpec::chunks`] / [`crate::dse::run_sweep_fold`] for grids
    /// that should never be materialized.
    pub fn points(&self) -> Vec<AdcQuery> {
        let len = self
            .checked_len()
            .expect("sweep grid too large to materialize; stream it with chunks()/run_sweep_fold");
        let mut out = Vec::with_capacity(len);
        for &enob in &self.enobs {
            for &total_throughput in &self.total_throughputs {
                for &tech_nm in &self.tech_nms {
                    for &n_adcs in &self.n_adcs {
                        out.push(AdcQuery { enob, total_throughput, tech_nm, n_adcs });
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_parse_roundtrips_and_rejects() {
        assert_eq!(SweepTier::parse("exact").unwrap(), SweepTier::Exact);
        assert_eq!(SweepTier::parse("fast").unwrap(), SweepTier::Fast);
        assert_eq!(SweepTier::default(), SweepTier::Exact);
        for t in [SweepTier::Exact, SweepTier::Fast] {
            assert_eq!(SweepTier::parse(t.name()).unwrap(), t);
        }
        let err = SweepTier::parse("turbo").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("turbo") && msg.contains("fast") && msg.contains("exact"), "{msg}");
    }

    #[test]
    fn cartesian_count_and_order() {
        let s = SweepSpec {
            enobs: vec![4.0, 8.0],
            total_throughputs: vec![1e8, 1e9],
            tech_nms: vec![32.0],
            n_adcs: vec![1, 2],
        };
        let pts = s.points();
        assert_eq!(pts.len(), s.len());
        assert_eq!(pts.len(), 8);
        assert_eq!(pts[0].n_adcs, 1);
        assert_eq!(pts[1].n_adcs, 2);
        assert_eq!(pts[0].enob, 4.0);
        assert_eq!(pts[7].enob, 8.0);
    }

    #[test]
    fn fig5_grid_matches_paper_ranges() {
        let s = SweepSpec::fig5(7.0, 5);
        assert_eq!(s.n_adcs, vec![1, 2, 4, 8, 16]);
        assert!((s.total_throughputs[0] - 1.3e9).abs() / 1.3e9 < 1e-9);
        assert!((s.total_throughputs[4] - 40e9).abs() / 40e9 < 1e-9);
        assert_eq!(s.len(), 25);
    }

    #[test]
    fn dense_grid_is_dense() {
        let s = SweepSpec::dense(10);
        assert_eq!(s.len(), 10 * 10 * 4 * 6);
    }

    #[test]
    fn point_at_matches_points() {
        let s = SweepSpec {
            enobs: vec![4.0, 8.0, 12.0],
            total_throughputs: vec![1e6, 1e8],
            tech_nms: vec![16.0, 32.0],
            n_adcs: vec![1, 2, 4],
        };
        let pts = s.points();
        for (i, p) in pts.iter().enumerate() {
            assert_eq!(&s.point_at(i), p, "index {i}");
        }
    }

    #[test]
    fn fill_range_matches_points_at_odd_boundaries() {
        let s = SweepSpec::dense(5);
        let pts = s.points();
        for (start, end) in [(0usize, 0usize), (0, 1), (3, 17), (0, pts.len()), (599, 600)] {
            let mut buf = Vec::new();
            s.fill_range(start..end, &mut buf);
            assert_eq!(buf.as_slice(), &pts[start..end], "{start}..{end}");
        }
    }

    #[test]
    fn chunks_cover_grid_in_order() {
        let s = SweepSpec::dense(4);
        let pts = s.points();
        for chunk in [1usize, 7, 64, 10_000] {
            let mut seen = Vec::new();
            let mut expect_start = 0usize;
            for (start, buf) in s.chunks(chunk) {
                assert_eq!(start, expect_start);
                expect_start += buf.len();
                seen.extend(buf);
            }
            assert_eq!(seen, pts, "chunk={chunk}");
        }
    }

    #[test]
    fn log_table_matches_query_bits() {
        let s = SweepSpec::dense(6);
        let table = s.log_per_adc_table();
        for (ti, &total) in s.total_throughputs.iter().enumerate() {
            for (ni, &n) in s.n_adcs.iter().enumerate() {
                let q = AdcQuery { enob: 8.0, total_throughput: total, tech_nm: 32.0, n_adcs: n };
                assert_eq!(
                    table[ti * s.n_adcs.len() + ni].to_bits(),
                    log10(q.throughput_per_adc()).to_bits()
                );
            }
        }
    }

    #[test]
    fn oversized_grid_saturates_instead_of_overflowing() {
        // 131072^3 * 131072 = 2^68 > usize::MAX: the axis product must
        // saturate deterministically, not wrap (debug vs release used to
        // disagree here).
        let s = SweepSpec {
            enobs: vec![8.0; 1 << 17],
            total_throughputs: vec![1e9; 1 << 17],
            tech_nms: vec![32.0; 1 << 17],
            n_adcs: vec![1; 1 << 17],
        };
        assert_eq!(s.checked_len(), None);
        assert_eq!(s.len(), usize::MAX);
        assert!(!s.is_empty());
    }

    #[test]
    #[should_panic(expected = "too large to materialize")]
    fn oversized_grid_refuses_to_materialize() {
        let s = SweepSpec {
            enobs: vec![8.0; 1 << 17],
            total_throughputs: vec![1e9; 1 << 17],
            tech_nms: vec![32.0; 1 << 17],
            n_adcs: vec![1; 1 << 17],
        };
        let _ = s.points();
    }

    #[test]
    fn spec_value_roundtrip_is_bit_exact() {
        let spec = SweepSpec {
            enobs: vec![2.0, 7.3000000000000007, 13.999999999999998],
            total_throughputs: vec![1.3e9, 4e10, f64::MIN_POSITIVE],
            tech_nms: vec![16.0, 32.0],
            n_adcs: vec![1, u32::MAX],
        };
        let text = spec.to_value().to_json_string().unwrap();
        let back = SweepSpec::from_value(&crate::config::parse_json(&text).unwrap()).unwrap();
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.enobs), bits(&spec.enobs));
        assert_eq!(bits(&back.total_throughputs), bits(&spec.total_throughputs));
        assert_eq!(bits(&back.tech_nms), bits(&spec.tech_nms));
        assert_eq!(back.n_adcs, spec.n_adcs);
    }

    #[test]
    fn spec_from_value_rejects_malformed_input() {
        use crate::config::parse_json;
        for text in [
            "{}",
            r#"{"enobs": [8], "total_throughputs": [1e9], "tech_nms": [32]}"#,
            r#"{"enobs": [8], "total_throughputs": [1e9], "tech_nms": [32], "n_adcs": [1.5]}"#,
            r#"{"enobs": [8], "total_throughputs": [1e9], "tech_nms": [32], "n_adcs": [-1]}"#,
            r#"{"enobs": ["x"], "total_throughputs": [1e9], "tech_nms": [32], "n_adcs": [1]}"#,
            r#"{"enobs": 8, "total_throughputs": [1e9], "tech_nms": [32], "n_adcs": [1]}"#,
        ] {
            let v = parse_json(text).unwrap();
            assert!(SweepSpec::from_value(&v).is_err(), "{text}");
        }
    }

    #[test]
    fn empty_axis_means_empty_grid() {
        let s = SweepSpec {
            enobs: vec![],
            total_throughputs: vec![1e9],
            tech_nms: vec![32.0],
            n_adcs: vec![1],
        };
        assert_eq!(s.len(), 0);
        assert!(s.is_empty());
        assert!(s.points().is_empty());
        assert_eq!(s.chunks(8).count(), 0);
        let mut buf = Vec::new();
        s.fill_range(0..0, &mut buf);
        assert!(buf.is_empty());
    }
}
