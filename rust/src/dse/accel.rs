//! Accelerator-level design-space exploration (§I claim 3: "explore CiM
//! accelerator designs using different ADCs").
//!
//! Where [`super::sweep`] explores raw ADC design points, this module
//! sweeps *architecture* knobs — analog sum size, ADC resolution, number
//! of ADCs, total ADC throughput — and evaluates each candidate with the
//! full mapper + component rollup on a workload, yielding
//! energy/area/latency/EAP and the Pareto-optimal configurations.

use crate::adc::AdcModel;
use crate::arch::CimArch;
use crate::arch::raella::{RaellaVariant, raella};
use crate::energy::{AreaScope, accel_area, eap, layer_energy};
use crate::error::Result;
use crate::exec::parallel_map;
use crate::mapper::map_layer;
use crate::workload::Workload;

use super::pareto::pareto_front;

/// The architecture knob grid.
#[derive(Clone, Debug)]
pub struct AccelSweepSpec {
    /// Analog sum sizes to try (values summed per convert).
    pub sum_sizes: Vec<usize>,
    /// ADC resolutions (ENOB) to try.
    pub enobs: Vec<f64>,
    /// Parallel ADC counts to try.
    pub n_adcs: Vec<u32>,
    /// Total ADC throughputs (converts/s) to try.
    pub total_throughputs: Vec<f64>,
    /// Fidelity coupling: a candidate is kept only if its ADC reads the
    /// analog sum with at most this many clipped bits
    /// (`lossless_enob(sum) - enob <= max_clipped_bits`). RAELLA-style
    /// speculation tolerates ~5.6 clipped bits (its XL point); without
    /// this constraint the lowest ENOB trivially dominates every sum size.
    pub max_clipped_bits: f64,
}

impl Default for AccelSweepSpec {
    /// A RAELLA-neighborhood grid: the paper's S/M/L/XL sum/ENOB points
    /// plus intermediate resolutions, Fig. 5's ADC counts, and a
    /// low/high throughput pair.
    fn default() -> Self {
        AccelSweepSpec {
            sum_sizes: vec![128, 256, 512, 1024, 2048, 4096, 8192],
            enobs: vec![5.0, 6.0, 7.0, 8.0, 9.0, 10.0],
            n_adcs: vec![1, 2, 4, 8, 16],
            total_throughputs: vec![1.3e9, 1.3e10],
            max_clipped_bits: 5.6,
        }
    }
}

impl AccelSweepSpec {
    /// Upper bound on candidate count (before the fidelity filter).
    pub fn len(&self) -> usize {
        self.sum_sizes.len() * self.enobs.len() * self.n_adcs.len()
            * self.total_throughputs.len()
    }

    /// Whether the grid is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize candidate architectures (RAELLA-M chassis, knobs
    /// swept, fidelity-infeasible combinations dropped).
    pub fn candidates(&self) -> Vec<CimArch> {
        let base = raella(RaellaVariant::Medium);
        let mut out = Vec::with_capacity(self.len());
        for &sum_size in &self.sum_sizes {
            for &enob in &self.enobs {
                for &n in &self.n_adcs {
                    for &tp in &self.total_throughputs {
                        let mut arch = base.clone();
                        arch.name = format!("sum{sum_size}-e{enob}-n{n}-t{tp:.1e}");
                        arch.sum_size = sum_size;
                        arch.adc.enob = enob;
                        arch.adc.n_adcs = n;
                        arch.adc.total_throughput = tp;
                        if arch.lossless_enob() - enob <= self.max_clipped_bits {
                            out.push(arch);
                        }
                    }
                }
            }
        }
        out
    }
}

/// One evaluated candidate architecture.
#[derive(Clone, Debug)]
pub struct AccelPoint {
    /// The candidate.
    pub arch: CimArch,
    /// Workload energy (pJ).
    pub energy_pj: f64,
    /// Tile area (µm²).
    pub area_um2: f64,
    /// ADC share of energy.
    pub adc_energy_fraction: f64,
    /// Workload ADC-bound latency (s).
    pub latency_s: f64,
    /// Energy-area product.
    pub eap: f64,
}

/// Evaluate every candidate on the workload (threaded).
///
/// `workers = 1` evaluates serially on the calling thread; any other
/// value routes through the shared [`crate::exec::Pool::global`], whose
/// fixed width (not `workers`) governs the actual parallelism.
pub fn run_accel_sweep(
    spec: &AccelSweepSpec,
    model: &AdcModel,
    workload: &Workload,
    workers: usize,
) -> Result<Vec<AccelPoint>> {
    let candidates = spec.candidates();
    let evaluated = parallel_map(&candidates, workers, |arch| -> Result<AccelPoint> {
        let mut energy = crate::energy::EnergyBreakdown::default();
        let mut latency_s = 0.0;
        let mut max_arrays = 0usize;
        for layer in &workload.layers {
            let m = map_layer(arch, layer)?;
            energy = energy.add(&layer_energy(arch, model, layer)?);
            latency_s += m.latency_s;
            max_arrays = max_arrays.max(m.arrays_used);
        }
        // Tile sized for the largest layer (weights are reloaded per layer
        // in this tile-level exploration).
        let area = accel_area(arch, model, AreaScope::Tile { n_arrays: max_arrays });
        Ok(AccelPoint {
            arch: arch.clone(),
            energy_pj: energy.total_pj(),
            area_um2: area.total_um2(),
            adc_energy_fraction: energy.adc_fraction(),
            latency_s,
            eap: eap(&energy, &area),
        })
    });
    evaluated.into_iter().collect()
}

/// Indices of the energy/area Pareto-optimal candidates.
pub fn accel_pareto(points: &[AccelPoint]) -> Vec<usize> {
    pareto_front(
        &points
            .iter()
            .map(|p| (p.energy_pj, p.area_um2))
            .collect::<Vec<_>>(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::lenet;

    fn small_spec() -> AccelSweepSpec {
        AccelSweepSpec {
            sum_sizes: vec![128, 512, 2048],
            enobs: vec![6.0, 8.0],
            n_adcs: vec![2, 8],
            total_throughputs: vec![1.3e9],
            max_clipped_bits: 5.6,
        }
    }

    #[test]
    fn sweep_evaluates_all_candidates() {
        let spec = small_spec();
        let pts = run_accel_sweep(&spec, &AdcModel::default(), &lenet(), 2).unwrap();
        assert_eq!(pts.len(), spec.candidates().len());
        assert!(pts.len() < spec.len(), "fidelity filter should drop some");
        for p in &pts {
            assert!(p.energy_pj > 0.0 && p.energy_pj.is_finite());
            assert!(p.area_um2 > 0.0);
            assert!(p.latency_s > 0.0);
            assert!((0.0..1.0).contains(&p.adc_energy_fraction));
            assert!((p.eap - p.energy_pj * p.area_um2).abs() / p.eap < 1e-12);
        }
    }

    #[test]
    fn pareto_front_nonempty_and_valid() {
        let pts = run_accel_sweep(&small_spec(), &AdcModel::default(), &lenet(), 1).unwrap();
        let front = accel_pareto(&pts);
        assert!(!front.is_empty());
        // The global-min-energy and global-min-area candidates are on it.
        let min_e = pts
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.energy_pj.total_cmp(&b.1.energy_pj))
            .unwrap()
            .0;
        assert!(front.contains(&min_e));
    }

    #[test]
    fn lenet_best_covers_reduction_at_lowest_enob() {
        // With ENOB and sum size as *independent* knobs (fidelity is
        // studied separately in the functional sim), the best-energy
        // candidate uses the smallest sum that still covers the largest
        // reduction (lenet max C·R·S = 400 -> 512) at the lowest ENOB:
        // fewer converts, cheapest converts.
        let pts = run_accel_sweep(&small_spec(), &AdcModel::default(), &lenet(), 1).unwrap();
        let best = pts
            .iter()
            .min_by(|a, b| a.energy_pj.total_cmp(&b.energy_pj))
            .unwrap();
        assert_eq!(best.arch.sum_size, 512, "best was {}", best.arch.name);
        assert_eq!(best.arch.adc.enob, 6.0);
        // The fidelity coupling removed sum-2048 @ 6b (it would clip
        // ~6.6 bits > 5.6 allowed), so oversizing cannot win for free.
        assert!(!pts.iter().any(|p| p.arch.sum_size == 2048 && p.arch.adc.enob == 6.0));
    }

    #[test]
    fn serial_and_parallel_agree() {
        let spec = small_spec();
        let model = AdcModel::default();
        let a = run_accel_sweep(&spec, &model, &lenet(), 1).unwrap();
        let b = run_accel_sweep(&spec, &model, &lenet(), 4).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arch.name, y.arch.name);
            assert_eq!(x.energy_pj, y.energy_pj);
        }
    }
}
