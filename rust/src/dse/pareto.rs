//! Pareto-front extraction for (minimize, minimize) objectives.

/// Indices of the Pareto-optimal points among `(a, b)` pairs where both
/// objectives are minimized. A point is kept iff no other point is <= in
/// both objectives and < in at least one. Returned indices are sorted by
/// the first objective ascending.
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..points.len()).collect();
    // Sort by a ascending, then b ascending.
    order.sort_by(|&i, &j| {
        points[i]
            .0
            .total_cmp(&points[j].0)
            .then(points[i].1.total_cmp(&points[j].1))
    });
    let mut front = Vec::new();
    let mut best_b = f64::INFINITY;
    for &i in &order {
        // Sorted by a ascending with b as tiebreak, a point is on the front
        // iff its b strictly improves on everything seen so far (anything
        // earlier has a <= ours, so equal-or-worse b means dominated/dup).
        if points[i].1 < best_b {
            front.push(i);
            best_b = points[i].1;
        }
    }
    front
}

/// Incremental Pareto front for streaming sweeps: points are pushed one
/// at a time (with their original index) and only the current
/// non-dominated set is retained, so a million-point sweep's front costs
/// front-sized memory, not sweep-sized.
///
/// Deterministic regardless of push/merge order: duplicates keep the
/// smallest original index, so [`StreamingFront::into_indices`] returns
/// exactly what [`pareto_front`] would on the materialized point list —
/// asserted by the property tests below and in `sweep_stream_properties`.
#[derive(Clone, Debug, Default)]
pub struct StreamingFront {
    /// Non-dominated `(a, b, original_index)` triples, unordered.
    pts: Vec<(f64, f64, usize)>,
}

impl StreamingFront {
    /// Empty front.
    pub fn new() -> StreamingFront {
        StreamingFront::default()
    }

    /// Number of points currently on the front.
    pub fn len(&self) -> usize {
        self.pts.len()
    }

    /// Whether the front is empty.
    pub fn is_empty(&self) -> bool {
        self.pts.is_empty()
    }

    /// Offer a point; it is kept only while non-dominated, and evicts any
    /// resident point it dominates. Non-finite objectives are dropped —
    /// NaN can neither dominate nor be dominated under `<=`, so keeping
    /// such points would make the front merge-order dependent
    /// ([`pareto_front`]'s behavior on NaN input is likewise unspecified;
    /// the equivalence contract covers finite objectives).
    pub fn push(&mut self, a: f64, b: f64, index: usize) {
        if !(a.is_finite() && b.is_finite()) {
            return;
        }
        for &mut (x, y, ref mut idx) in &mut self.pts {
            if x == a && y == b {
                // Exact duplicate: keep the earliest index (what the
                // stable sort inside `pareto_front` keeps).
                *idx = (*idx).min(index);
                return;
            }
            if x <= a && y <= b {
                return; // dominated by a resident point
            }
        }
        self.pts.retain(|&(x, y, _)| !(a <= x && b <= y));
        self.pts.push((a, b, index));
    }

    /// Merge another front in (used to combine per-worker fronts).
    pub fn merge(mut self, other: StreamingFront) -> StreamingFront {
        for (a, b, idx) in other.pts {
            self.push(a, b, idx);
        }
        self
    }

    /// The front's original indices, sorted by the first objective
    /// ascending — the same order/content [`pareto_front`] returns.
    pub fn into_indices(mut self) -> Vec<usize> {
        self.pts
            .sort_by(|p, q| p.0.total_cmp(&q.0).then(p.1.total_cmp(&q.1)));
        self.pts.into_iter().map(|(_, _, i)| i).collect()
    }
}

/// Hypervolume-style scalar summary: the best (minimum) product a·b on the
/// front — a quick "knee" indicator used in sweep reports.
pub fn best_product(points: &[(f64, f64)]) -> Option<(usize, f64)> {
    points
        .iter()
        .enumerate()
        .map(|(i, &(a, b))| (i, a * b))
        .min_by(|x, y| x.1.total_cmp(&y.1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{Config, check};
    use crate::util::Rng;

    #[test]
    fn simple_front() {
        let pts = vec![
            (1.0, 10.0), // front
            (2.0, 5.0),  // front
            (3.0, 6.0),  // dominated by (2,5)
            (4.0, 1.0),  // front
            (4.0, 2.0),  // dominated
        ];
        let f = pareto_front(&pts);
        assert_eq!(f, vec![0, 1, 3]);
    }

    #[test]
    fn single_point_is_front() {
        assert_eq!(pareto_front(&[(1.0, 1.0)]), vec![0]);
        assert!(pareto_front(&[]).is_empty());
    }

    #[test]
    fn duplicate_points_keep_one() {
        let pts = vec![(1.0, 1.0), (1.0, 1.0)];
        let f = pareto_front(&pts);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn property_no_front_point_is_dominated() {
        check(Config::default().cases(50), |rng: &mut Rng| {
            let n = 3 + rng.index(60);
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| (rng.uniform(0.0, 10.0), rng.uniform(0.0, 10.0)))
                .collect();
            let front = pareto_front(&pts);
            assert!(!front.is_empty());
            for &i in &front {
                for (j, &(a, b)) in pts.iter().enumerate() {
                    if i == j {
                        continue;
                    }
                    let dominated = a <= pts[i].0
                        && b <= pts[i].1
                        && (a < pts[i].0 || b < pts[i].1);
                    assert!(!dominated, "front point {i} dominated by {j}");
                }
            }
        });
    }

    #[test]
    fn property_every_non_front_point_is_dominated() {
        check(Config::default().cases(50).seed(99), |rng: &mut Rng| {
            let n = 3 + rng.index(40);
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| (rng.uniform(0.0, 4.0).round(), rng.uniform(0.0, 4.0).round()))
                .collect();
            let front = pareto_front(&pts);
            for (j, &(a, b)) in pts.iter().enumerate() {
                if front.contains(&j) {
                    continue;
                }
                let dominated_or_dup = pts.iter().enumerate().any(|(i, &(x, y))| {
                    i != j && x <= a && y <= b
                });
                assert!(dominated_or_dup, "non-front point {j} not dominated");
            }
        });
    }

    #[test]
    fn best_product_finds_knee() {
        let pts = vec![(10.0, 1.0), (3.0, 3.0), (1.0, 10.0)];
        let (i, p) = best_product(&pts).unwrap();
        assert_eq!(i, 1);
        assert!((p - 9.0).abs() < 1e-12);
    }
}
