//! Pareto-front extraction for (minimize, minimize) objectives.

use crate::config::{Value, f64_from_bits_hex, f64_to_bits_hex};
use crate::error::{Error, Result};

/// Indices of the Pareto-optimal points among `(a, b)` pairs where both
/// objectives are minimized. A point is kept iff no other point is <= in
/// both objectives and < in at least one. Returned indices are sorted by
/// the first objective ascending.
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..points.len()).collect();
    // Sort by a ascending, then b ascending.
    order.sort_by(|&i, &j| {
        points[i]
            .0
            .total_cmp(&points[j].0)
            .then(points[i].1.total_cmp(&points[j].1))
    });
    let mut front = Vec::new();
    let mut best_b = f64::INFINITY;
    for &i in &order {
        // Sorted by a ascending with b as tiebreak, a point is on the front
        // iff its b strictly improves on everything seen so far (anything
        // earlier has a <= ours, so equal-or-worse b means dominated/dup).
        if points[i].1 < best_b {
            front.push(i);
            best_b = points[i].1;
        }
    }
    front
}

/// Incremental Pareto front for streaming sweeps: points are pushed one
/// at a time (with their original index) and only the current
/// non-dominated set is retained, so a million-point sweep's front costs
/// front-sized memory, not sweep-sized.
///
/// Deterministic regardless of push/merge order: duplicates keep the
/// smallest original index, so [`StreamingFront::into_indices`] returns
/// exactly what [`pareto_front`] would on the materialized point list —
/// asserted by the property tests below and in `sweep_stream_properties`.
#[derive(Clone, Debug, Default)]
pub struct StreamingFront {
    /// Non-dominated `(a, b, original_index)` triples, unordered.
    pts: Vec<(f64, f64, usize)>,
}

impl StreamingFront {
    /// Empty front.
    pub fn new() -> StreamingFront {
        StreamingFront::default()
    }

    /// Number of points currently on the front.
    pub fn len(&self) -> usize {
        self.pts.len()
    }

    /// Whether the front is empty.
    pub fn is_empty(&self) -> bool {
        self.pts.is_empty()
    }

    /// Offer a point; it is kept only while non-dominated, and evicts any
    /// resident point it dominates. Non-finite objectives are dropped —
    /// NaN can neither dominate nor be dominated under `<=`, so keeping
    /// such points would make the front merge-order dependent
    /// ([`pareto_front`]'s behavior on NaN input is likewise unspecified;
    /// the equivalence contract covers finite objectives).
    pub fn push(&mut self, a: f64, b: f64, index: usize) {
        if !(a.is_finite() && b.is_finite()) {
            return;
        }
        for &mut (x, y, ref mut idx) in &mut self.pts {
            if x == a && y == b {
                // Exact duplicate: keep the earliest index (what the
                // stable sort inside `pareto_front` keeps).
                *idx = (*idx).min(index);
                return;
            }
            if x <= a && y <= b {
                return; // dominated by a resident point
            }
        }
        self.pts.retain(|&(x, y, _)| !(a <= x && b <= y));
        self.pts.push((a, b, index));
    }

    /// Merge another front in (used to combine per-worker fronts).
    pub fn merge(mut self, other: StreamingFront) -> StreamingFront {
        for (a, b, idx) in other.pts {
            self.push(a, b, idx);
        }
        self
    }

    /// The front's original indices, sorted by the first objective
    /// ascending — the same order/content [`pareto_front`] returns.
    pub fn into_indices(mut self) -> Vec<usize> {
        self.pts
            .sort_by(|p, q| p.0.total_cmp(&q.0).then(p.1.total_cmp(&q.1)));
        self.pts.into_iter().map(|(_, _, i)| i).collect()
    }

    /// Non-consuming [`StreamingFront::into_indices`].
    pub fn indices(&self) -> Vec<usize> {
        self.clone().into_indices()
    }

    /// The resident `(a, b, original_index)` triples, unordered.
    pub fn points(&self) -> &[(f64, f64, usize)] {
        &self.pts
    }

    /// Rebuild a front by re-offering every triple — the dominance
    /// invariant is re-established even if the input is not a valid front
    /// (extra dominated points are simply dropped again).
    pub fn from_points<I: IntoIterator<Item = (f64, f64, usize)>>(points: I) -> StreamingFront {
        let mut front = StreamingFront::new();
        for (a, b, index) in points {
            front.push(a, b, index);
        }
        front
    }

    /// Serialize as a canonical [`Value`]: `[[a_hex, b_hex, index], ...]`
    /// sorted by objectives ascending. Objectives travel as IEEE-754 bit
    /// patterns ([`f64_to_bits_hex`]) so a front written by one process
    /// and merged in another stays bit-identical to an in-process merge.
    pub fn to_value(&self) -> Value {
        let mut pts = self.pts.clone();
        pts.sort_by(|p, q| p.0.total_cmp(&q.0).then(p.1.total_cmp(&q.1)));
        Value::Array(
            pts.into_iter()
                .map(|(a, b, index)| {
                    Value::Array(vec![
                        Value::String(f64_to_bits_hex(a)),
                        Value::String(f64_to_bits_hex(b)),
                        Value::Number(index as f64),
                    ])
                })
                .collect(),
        )
    }

    /// Inverse of [`StreamingFront::to_value`] (points are re-offered, so
    /// a tampered payload degrades to a smaller front, never a panic).
    pub fn from_value(v: &Value) -> Result<StreamingFront> {
        let items = v
            .as_array()
            .ok_or_else(|| Error::Config("front payload is not an array".into()))?;
        let mut front = StreamingFront::new();
        for (i, item) in items.iter().enumerate() {
            let triple = item
                .as_array()
                .filter(|t| t.len() == 3)
                .ok_or_else(|| {
                    Error::Config(format!("front entry {i} is not an [a, b, index] triple"))
                })?;
            let a = f64_from_bits_hex(triple[0].as_str().ok_or_else(|| {
                Error::Config(format!("front entry {i}: objective `a` is not a bit string"))
            })?)?;
            let b = f64_from_bits_hex(triple[1].as_str().ok_or_else(|| {
                Error::Config(format!("front entry {i}: objective `b` is not a bit string"))
            })?)?;
            let index = triple[2].as_usize().ok_or_else(|| {
                Error::Config(format!("front entry {i}: index is not a non-negative integer"))
            })?;
            front.push(a, b, index);
        }
        Ok(front)
    }
}

/// Hypervolume-style scalar summary: the best (minimum) product a·b on the
/// front — a quick "knee" indicator used in sweep reports.
pub fn best_product(points: &[(f64, f64)]) -> Option<(usize, f64)> {
    points
        .iter()
        .enumerate()
        .map(|(i, &(a, b))| (i, a * b))
        .min_by(|x, y| x.1.total_cmp(&y.1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{Config, check};
    use crate::util::Rng;

    #[test]
    fn simple_front() {
        let pts = vec![
            (1.0, 10.0), // front
            (2.0, 5.0),  // front
            (3.0, 6.0),  // dominated by (2,5)
            (4.0, 1.0),  // front
            (4.0, 2.0),  // dominated
        ];
        let f = pareto_front(&pts);
        assert_eq!(f, vec![0, 1, 3]);
    }

    #[test]
    fn single_point_is_front() {
        assert_eq!(pareto_front(&[(1.0, 1.0)]), vec![0]);
        assert!(pareto_front(&[]).is_empty());
    }

    #[test]
    fn duplicate_points_keep_one() {
        let pts = vec![(1.0, 1.0), (1.0, 1.0)];
        let f = pareto_front(&pts);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn property_no_front_point_is_dominated() {
        check(Config::default().cases(50), |rng: &mut Rng| {
            let n = 3 + rng.index(60);
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| (rng.uniform(0.0, 10.0), rng.uniform(0.0, 10.0)))
                .collect();
            let front = pareto_front(&pts);
            assert!(!front.is_empty());
            for &i in &front {
                for (j, &(a, b)) in pts.iter().enumerate() {
                    if i == j {
                        continue;
                    }
                    let dominated = a <= pts[i].0
                        && b <= pts[i].1
                        && (a < pts[i].0 || b < pts[i].1);
                    assert!(!dominated, "front point {i} dominated by {j}");
                }
            }
        });
    }

    #[test]
    fn property_every_non_front_point_is_dominated() {
        check(Config::default().cases(50).seed(99), |rng: &mut Rng| {
            let n = 3 + rng.index(40);
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| (rng.uniform(0.0, 4.0).round(), rng.uniform(0.0, 4.0).round()))
                .collect();
            let front = pareto_front(&pts);
            for (j, &(a, b)) in pts.iter().enumerate() {
                if front.contains(&j) {
                    continue;
                }
                let dominated_or_dup = pts.iter().enumerate().any(|(i, &(x, y))| {
                    i != j && x <= a && y <= b
                });
                assert!(dominated_or_dup, "non-front point {j} not dominated");
            }
        });
    }

    #[test]
    fn best_product_finds_knee() {
        let pts = vec![(10.0, 1.0), (3.0, 3.0), (1.0, 10.0)];
        let (i, p) = best_product(&pts).unwrap();
        assert_eq!(i, 1);
        assert!((p - 9.0).abs() < 1e-12);
    }

    #[test]
    fn streaming_front_drops_non_finite_without_panicking() {
        let mut f = StreamingFront::new();
        f.push(f64::NAN, 1.0, 0);
        f.push(1.0, f64::NAN, 1);
        f.push(f64::INFINITY, 1.0, 2);
        f.push(1.0, f64::NEG_INFINITY, 3);
        f.push(f64::NAN, f64::INFINITY, 4);
        assert!(f.is_empty());
        f.push(2.0, 2.0, 5);
        assert_eq!(f.len(), 1);
        // Merging fronts that saw non-finite pushes never panics either.
        let merged = f.clone().merge(StreamingFront::from_points(vec![
            (f64::NAN, 0.0, 6),
            (1.0, 3.0, 7),
        ]));
        assert_eq!(merged.into_indices(), vec![7, 5]);
    }

    #[test]
    fn streaming_front_serialization_is_bit_exact() {
        let mut f = StreamingFront::new();
        // Values with tricky bit patterns: subnormal, -0.0-adjacent, huge.
        f.push(f64::MIN_POSITIVE, 1e300, 3);
        f.push(1e300, f64::MIN_POSITIVE, 9);
        f.push(0.5, 0.25, 4);
        let v = f.to_value();
        let back = StreamingFront::from_value(&v).unwrap();
        let mut a: Vec<(u64, u64, usize)> =
            f.points().iter().map(|&(x, y, i)| (x.to_bits(), y.to_bits(), i)).collect();
        let mut b: Vec<(u64, u64, usize)> =
            back.points().iter().map(|&(x, y, i)| (x.to_bits(), y.to_bits(), i)).collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
        // And through the JSON text layer.
        let text = v.to_json_string().unwrap();
        let reparsed = StreamingFront::from_value(&crate::config::parse_json(&text).unwrap())
            .unwrap();
        assert_eq!(reparsed.indices(), f.indices());
    }

    #[test]
    fn streaming_front_from_value_rejects_malformed_payloads() {
        use crate::config::parse_json;
        for text in [
            "{}",
            "[[1, 2, 3]]",
            "[[\"3ff0000000000000\", \"zz\", 0]]",
            "[[\"3ff0000000000000\", \"3ff0000000000000\"]]",
            "[[\"3ff0000000000000\", \"3ff0000000000000\", -1]]",
        ] {
            let v = parse_json(text).unwrap();
            assert!(StreamingFront::from_value(&v).is_err(), "{text}");
        }
    }
}
