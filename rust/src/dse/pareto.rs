//! Pareto-front extraction for (minimize, minimize) objectives.

use crate::config::{Value, f64_from_bits_hex, f64_to_bits_hex};
use crate::error::{Error, Result};

/// Indices of the Pareto-optimal points among `(a, b)` pairs where both
/// objectives are minimized. A point is kept iff no other point is <= in
/// both objectives and < in at least one. Returned indices are sorted by
/// the first objective ascending.
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..points.len()).collect();
    // Sort by a ascending, then b ascending.
    order.sort_by(|&i, &j| {
        points[i]
            .0
            .total_cmp(&points[j].0)
            .then(points[i].1.total_cmp(&points[j].1))
    });
    let mut front = Vec::new();
    let mut best_b = f64::INFINITY;
    for &i in &order {
        // Sorted by a ascending with b as tiebreak, a point is on the front
        // iff its b strictly improves on everything seen so far (anything
        // earlier has a <= ours, so equal-or-worse b means dominated/dup).
        if points[i].1 < best_b {
            front.push(i);
            best_b = points[i].1;
        }
    }
    front
}

/// Incremental Pareto front for streaming sweeps: points are pushed one
/// at a time (with their original index) and only the current
/// non-dominated set is retained, so a million-point sweep's front costs
/// front-sized memory, not sweep-sized.
///
/// Deterministic regardless of push/merge order: duplicates keep the
/// smallest original index, so [`StreamingFront::into_indices`] returns
/// exactly what [`pareto_front`] would on the materialized point list —
/// asserted by the property tests below and in `sweep_stream_properties`.
#[derive(Clone, Debug, Default)]
pub struct StreamingFront {
    /// Non-dominated `(a, b, original_index)` triples, unordered.
    pts: Vec<(f64, f64, usize)>,
}

impl StreamingFront {
    /// Empty front.
    pub fn new() -> StreamingFront {
        StreamingFront::default()
    }

    /// Number of points currently on the front.
    pub fn len(&self) -> usize {
        self.pts.len()
    }

    /// Whether the front is empty.
    pub fn is_empty(&self) -> bool {
        self.pts.is_empty()
    }

    /// Offer a point; it is kept only while non-dominated, and evicts any
    /// resident point it dominates. Non-finite objectives are dropped —
    /// NaN can neither dominate nor be dominated under `<=`, so keeping
    /// such points would make the front merge-order dependent
    /// ([`pareto_front`]'s behavior on NaN input is likewise unspecified;
    /// the equivalence contract covers finite objectives).
    pub fn push(&mut self, a: f64, b: f64, index: usize) {
        if !(a.is_finite() && b.is_finite()) {
            return;
        }
        for &mut (x, y, ref mut idx) in &mut self.pts {
            if x == a && y == b {
                // Exact duplicate: keep the earliest index (what the
                // stable sort inside `pareto_front` keeps).
                *idx = (*idx).min(index);
                return;
            }
            if x <= a && y <= b {
                return; // dominated by a resident point
            }
        }
        self.pts.retain(|&(x, y, _)| !(a <= x && b <= y));
        self.pts.push((a, b, index));
    }

    /// Merge another front in (used to combine per-worker fronts).
    pub fn merge(mut self, other: StreamingFront) -> StreamingFront {
        for (a, b, idx) in other.pts {
            self.push(a, b, idx);
        }
        self
    }

    /// The front's original indices, sorted by the first objective
    /// ascending — the same order/content [`pareto_front`] returns.
    pub fn into_indices(mut self) -> Vec<usize> {
        self.pts
            .sort_by(|p, q| p.0.total_cmp(&q.0).then(p.1.total_cmp(&q.1)));
        self.pts.into_iter().map(|(_, _, i)| i).collect()
    }

    /// Non-consuming [`StreamingFront::into_indices`].
    pub fn indices(&self) -> Vec<usize> {
        self.clone().into_indices()
    }

    /// The resident `(a, b, original_index)` triples, unordered.
    pub fn points(&self) -> &[(f64, f64, usize)] {
        &self.pts
    }

    /// Rebuild a front by re-offering every triple — the dominance
    /// invariant is re-established even if the input is not a valid front
    /// (extra dominated points are simply dropped again).
    pub fn from_points<I: IntoIterator<Item = (f64, f64, usize)>>(points: I) -> StreamingFront {
        let mut front = StreamingFront::new();
        for (a, b, index) in points {
            front.push(a, b, index);
        }
        front
    }

    /// Serialize as a canonical [`Value`]: `[[a_hex, b_hex, index], ...]`
    /// sorted by objectives ascending. Objectives travel as IEEE-754 bit
    /// patterns ([`f64_to_bits_hex`]) so a front written by one process
    /// and merged in another stays bit-identical to an in-process merge.
    pub fn to_value(&self) -> Value {
        let mut pts = self.pts.clone();
        pts.sort_by(|p, q| p.0.total_cmp(&q.0).then(p.1.total_cmp(&q.1)));
        Value::Array(
            pts.into_iter()
                .map(|(a, b, index)| {
                    Value::Array(vec![
                        Value::String(f64_to_bits_hex(a)),
                        Value::String(f64_to_bits_hex(b)),
                        Value::Number(index as f64),
                    ])
                })
                .collect(),
        )
    }

    /// Inverse of [`StreamingFront::to_value`] (points are re-offered, so
    /// a tampered payload degrades to a smaller front, never a panic).
    pub fn from_value(v: &Value) -> Result<StreamingFront> {
        let items = v
            .as_array()
            .ok_or_else(|| Error::Config("front payload is not an array".into()))?;
        let mut front = StreamingFront::new();
        for (i, item) in items.iter().enumerate() {
            let triple = item
                .as_array()
                .filter(|t| t.len() == 3)
                .ok_or_else(|| {
                    Error::Config(format!("front entry {i} is not an [a, b, index] triple"))
                })?;
            let a = f64_from_bits_hex(triple[0].as_str().ok_or_else(|| {
                Error::Config(format!("front entry {i}: objective `a` is not a bit string"))
            })?)?;
            let b = f64_from_bits_hex(triple[1].as_str().ok_or_else(|| {
                Error::Config(format!("front entry {i}: objective `b` is not a bit string"))
            })?)?;
            let index = triple[2].as_usize().ok_or_else(|| {
                Error::Config(format!("front entry {i}: index is not a non-negative integer"))
            })?;
            front.push(a, b, index);
        }
        Ok(front)
    }
}

/// Total lexicographic order over K-objective rows (`total_cmp` per
/// coordinate): the canonical ordering [`FrontK::into_indices`],
/// [`FrontK::to_value`], and [`pareto_front_k`] all sort by.
fn cmp_objectives<const K: usize>(a: &[f64; K], b: &[f64; K]) -> std::cmp::Ordering {
    for j in 0..K {
        let c = a[j].total_cmp(&b[j]);
        if c != std::cmp::Ordering::Equal {
            return c;
        }
    }
    std::cmp::Ordering::Equal
}

/// Indices of the Pareto-optimal points among K-objective rows where
/// every objective is minimized: a point is kept iff no other point is
/// `<=` in all objectives and `<` in at least one.
///
/// Unlike the 2-objective [`pareto_front`] (whose behavior on NaN input
/// is unspecified), rows containing any non-finite objective are
/// *skipped*, exactly as [`FrontK::push`] drops them — so this
/// materialized reference and the streaming front return identical index
/// sets under arbitrary NaN/±∞ injection, not just on finite inputs.
/// Exact-duplicate rows keep the smallest index. Returned indices are
/// sorted lexicographically by objective ([`FrontK::into_indices`]'s
/// order).
pub fn pareto_front_k<const K: usize>(points: &[[f64; K]]) -> Vec<usize> {
    let finite: Vec<usize> = (0..points.len())
        .filter(|&i| points[i].iter().all(|x| x.is_finite()))
        .collect();
    let mut front = Vec::new();
    'candidate: for &i in &finite {
        let p = &points[i];
        for &j in &finite {
            if j == i {
                continue;
            }
            let q = &points[j];
            if q == p {
                if j < i {
                    continue 'candidate; // duplicate: the earliest index wins
                }
                continue;
            }
            if q.iter().zip(p.iter()).all(|(a, b)| a <= b) {
                continue 'candidate; // strictly dominated (q != p, q <= p)
            }
        }
        front.push(i);
    }
    front.sort_by(|&i, &j| cmp_objectives(&points[i], &points[j]));
    front
}

/// K-objective generalization of [`StreamingFront`]: non-dominated
/// `([f64; K], original_index)` pairs under minimize-everything
/// dominance, with the same streaming contract — order-independent
/// push/merge, non-finite rows dropped, exact duplicates keep the
/// smallest index — and the same bit-hex serialization scheme.
///
/// [`StreamingFront`] itself stays as the dedicated 2-objective engine:
/// its `(f64, f64, usize)` triples and payload shape are pinned by shard
/// artifact fingerprints and golden figures, so the generalization lives
/// beside it rather than replacing it.
#[derive(Clone, Debug)]
pub struct FrontK<const K: usize> {
    /// Non-dominated `(objectives, original_index)` pairs, unordered.
    pts: Vec<([f64; K], usize)>,
}

impl<const K: usize> Default for FrontK<K> {
    fn default() -> Self {
        FrontK { pts: Vec::new() }
    }
}

impl<const K: usize> FrontK<K> {
    /// Empty front.
    pub fn new() -> FrontK<K> {
        FrontK::default()
    }

    /// Number of points currently on the front.
    pub fn len(&self) -> usize {
        self.pts.len()
    }

    /// Whether the front is empty.
    pub fn is_empty(&self) -> bool {
        self.pts.is_empty()
    }

    /// Offer a point; it is kept only while non-dominated, and evicts any
    /// resident point it dominates. Rows with any non-finite objective
    /// are dropped, mirroring [`StreamingFront::push`] (NaN can neither
    /// dominate nor be dominated under `<=`, so keeping such rows would
    /// make the front merge-order dependent).
    pub fn push(&mut self, objectives: [f64; K], index: usize) {
        if objectives.iter().any(|x| !x.is_finite()) {
            return;
        }
        for &mut (resident, ref mut idx) in &mut self.pts {
            if resident == objectives {
                // Exact duplicate: keep the earliest index.
                *idx = (*idx).min(index);
                return;
            }
            if resident.iter().zip(objectives.iter()).all(|(r, o)| r <= o) {
                return; // dominated by a resident point
            }
        }
        self.pts
            .retain(|&(resident, _)| !objectives.iter().zip(resident.iter()).all(|(o, r)| o <= r));
        self.pts.push((objectives, index));
    }

    /// Merge another front in (used to combine per-worker fronts).
    pub fn merge(mut self, other: FrontK<K>) -> FrontK<K> {
        for (objectives, idx) in other.pts {
            self.push(objectives, idx);
        }
        self
    }

    /// The front's original indices, sorted lexicographically by
    /// objective — the same order/content [`pareto_front_k`] returns.
    pub fn into_indices(mut self) -> Vec<usize> {
        self.pts.sort_by(|p, q| cmp_objectives(&p.0, &q.0));
        self.pts.into_iter().map(|(_, i)| i).collect()
    }

    /// Non-consuming [`FrontK::into_indices`].
    pub fn indices(&self) -> Vec<usize> {
        self.clone().into_indices()
    }

    /// The resident `(objectives, original_index)` pairs, unordered.
    pub fn points(&self) -> &[([f64; K], usize)] {
        &self.pts
    }

    /// Rebuild a front by re-offering every pair — the dominance
    /// invariant is re-established even if the input is not a valid
    /// front.
    pub fn from_points<I: IntoIterator<Item = ([f64; K], usize)>>(points: I) -> FrontK<K> {
        let mut front = FrontK::new();
        for (objectives, index) in points {
            front.push(objectives, index);
        }
        front
    }

    /// Serialize as a canonical [`Value`]:
    /// `[[obj_hex_0, ..., obj_hex_{K-1}, index], ...]` sorted
    /// lexicographically by objective — the K-ary extension of
    /// [`StreamingFront::to_value`]'s bit-hex triples.
    pub fn to_value(&self) -> Value {
        let mut pts = self.pts.clone();
        pts.sort_by(|p, q| cmp_objectives(&p.0, &q.0));
        Value::Array(
            pts.into_iter()
                .map(|(objectives, index)| {
                    let mut row: Vec<Value> = objectives
                        .iter()
                        .map(|&x| Value::String(f64_to_bits_hex(x)))
                        .collect();
                    row.push(Value::Number(index as f64));
                    Value::Array(row)
                })
                .collect(),
        )
    }

    /// Inverse of [`FrontK::to_value`] (points are re-offered, so a
    /// tampered payload degrades to a smaller front, never a panic).
    pub fn from_value(v: &Value) -> Result<FrontK<K>> {
        let items = v
            .as_array()
            .ok_or_else(|| Error::Config("front payload is not an array".into()))?;
        let mut front = FrontK::new();
        for (i, item) in items.iter().enumerate() {
            let row = item.as_array().filter(|r| r.len() == K + 1).ok_or_else(|| {
                Error::Config(format!(
                    "front entry {i} is not a [{K} objectives, index] row"
                ))
            })?;
            let mut objectives = [0.0f64; K];
            for (j, slot) in objectives.iter_mut().enumerate() {
                *slot = f64_from_bits_hex(row[j].as_str().ok_or_else(|| {
                    Error::Config(format!(
                        "front entry {i}: objective {j} is not a bit string"
                    ))
                })?)?;
            }
            let index = row[K].as_usize().ok_or_else(|| {
                Error::Config(format!("front entry {i}: index is not a non-negative integer"))
            })?;
            front.push(objectives, index);
        }
        Ok(front)
    }
}

/// Hypervolume-style scalar summary: the best (minimum) product a·b on the
/// front — a quick "knee" indicator used in sweep reports.
pub fn best_product(points: &[(f64, f64)]) -> Option<(usize, f64)> {
    points
        .iter()
        .enumerate()
        .map(|(i, &(a, b))| (i, a * b))
        .min_by(|x, y| x.1.total_cmp(&y.1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{Config, check};
    use crate::util::Rng;

    #[test]
    fn simple_front() {
        let pts = vec![
            (1.0, 10.0), // front
            (2.0, 5.0),  // front
            (3.0, 6.0),  // dominated by (2,5)
            (4.0, 1.0),  // front
            (4.0, 2.0),  // dominated
        ];
        let f = pareto_front(&pts);
        assert_eq!(f, vec![0, 1, 3]);
    }

    #[test]
    fn single_point_is_front() {
        assert_eq!(pareto_front(&[(1.0, 1.0)]), vec![0]);
        assert!(pareto_front(&[]).is_empty());
    }

    #[test]
    fn duplicate_points_keep_one() {
        let pts = vec![(1.0, 1.0), (1.0, 1.0)];
        let f = pareto_front(&pts);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn property_no_front_point_is_dominated() {
        check(Config::default().cases(50), |rng: &mut Rng| {
            let n = 3 + rng.index(60);
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| (rng.uniform(0.0, 10.0), rng.uniform(0.0, 10.0)))
                .collect();
            let front = pareto_front(&pts);
            assert!(!front.is_empty());
            for &i in &front {
                for (j, &(a, b)) in pts.iter().enumerate() {
                    if i == j {
                        continue;
                    }
                    let dominated = a <= pts[i].0
                        && b <= pts[i].1
                        && (a < pts[i].0 || b < pts[i].1);
                    assert!(!dominated, "front point {i} dominated by {j}");
                }
            }
        });
    }

    #[test]
    fn property_every_non_front_point_is_dominated() {
        check(Config::default().cases(50).seed(99), |rng: &mut Rng| {
            let n = 3 + rng.index(40);
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| (rng.uniform(0.0, 4.0).round(), rng.uniform(0.0, 4.0).round()))
                .collect();
            let front = pareto_front(&pts);
            for (j, &(a, b)) in pts.iter().enumerate() {
                if front.contains(&j) {
                    continue;
                }
                let dominated_or_dup = pts.iter().enumerate().any(|(i, &(x, y))| {
                    i != j && x <= a && y <= b
                });
                assert!(dominated_or_dup, "non-front point {j} not dominated");
            }
        });
    }

    #[test]
    fn best_product_finds_knee() {
        let pts = vec![(10.0, 1.0), (3.0, 3.0), (1.0, 10.0)];
        let (i, p) = best_product(&pts).unwrap();
        assert_eq!(i, 1);
        assert!((p - 9.0).abs() < 1e-12);
    }

    #[test]
    fn streaming_front_drops_non_finite_without_panicking() {
        let mut f = StreamingFront::new();
        f.push(f64::NAN, 1.0, 0);
        f.push(1.0, f64::NAN, 1);
        f.push(f64::INFINITY, 1.0, 2);
        f.push(1.0, f64::NEG_INFINITY, 3);
        f.push(f64::NAN, f64::INFINITY, 4);
        assert!(f.is_empty());
        f.push(2.0, 2.0, 5);
        assert_eq!(f.len(), 1);
        // Merging fronts that saw non-finite pushes never panics either.
        let merged = f.clone().merge(StreamingFront::from_points(vec![
            (f64::NAN, 0.0, 6),
            (1.0, 3.0, 7),
        ]));
        assert_eq!(merged.into_indices(), vec![7, 5]);
    }

    #[test]
    fn streaming_front_serialization_is_bit_exact() {
        let mut f = StreamingFront::new();
        // Values with tricky bit patterns: subnormal, -0.0-adjacent, huge.
        f.push(f64::MIN_POSITIVE, 1e300, 3);
        f.push(1e300, f64::MIN_POSITIVE, 9);
        f.push(0.5, 0.25, 4);
        let v = f.to_value();
        let back = StreamingFront::from_value(&v).unwrap();
        let mut a: Vec<(u64, u64, usize)> =
            f.points().iter().map(|&(x, y, i)| (x.to_bits(), y.to_bits(), i)).collect();
        let mut b: Vec<(u64, u64, usize)> =
            back.points().iter().map(|&(x, y, i)| (x.to_bits(), y.to_bits(), i)).collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
        // And through the JSON text layer.
        let text = v.to_json_string().unwrap();
        let reparsed = StreamingFront::from_value(&crate::config::parse_json(&text).unwrap())
            .unwrap();
        assert_eq!(reparsed.indices(), f.indices());
    }

    /// Random K=3 rows with NaN/±∞ injection: the streaming front and the
    /// materialized [`pareto_front_k`] must return identical index sets
    /// regardless of push order, and merging split halves must match a
    /// single-pass build.
    #[test]
    fn front_k_matches_materialized_front_under_nan_injection() {
        check(Config::default().cases(60), |rng: &mut Rng| {
            let n = 3 + rng.index(50);
            let rows: Vec<[f64; 3]> = (0..n)
                .map(|_| {
                    let mut row = [
                        rng.uniform(0.0, 4.0).round(),
                        rng.uniform(0.0, 4.0).round(),
                        rng.uniform(0.0, 4.0).round(),
                    ];
                    if rng.index(5) == 0 {
                        row[rng.index(3)] = match rng.index(3) {
                            0 => f64::NAN,
                            1 => f64::INFINITY,
                            _ => f64::NEG_INFINITY,
                        };
                    }
                    row
                })
                .collect();
            let reference = pareto_front_k(&rows);

            // Forward build.
            let forward = FrontK::from_points(rows.iter().enumerate().map(|(i, &r)| (r, i)));
            assert_eq!(forward.indices(), reference);

            // Reverse build: push order must not matter.
            let reverse =
                FrontK::from_points(rows.iter().enumerate().rev().map(|(i, &r)| (r, i)));
            assert_eq!(reverse.indices(), reference);

            // Split-and-merge, both merge directions.
            let cut = rng.index(n + 1);
            let lo = FrontK::from_points(
                rows.iter().enumerate().take(cut).map(|(i, &r)| (r, i)),
            );
            let hi = FrontK::from_points(
                rows.iter().enumerate().skip(cut).map(|(i, &r)| (r, i)),
            );
            assert_eq!(lo.clone().merge(hi.clone()).into_indices(), reference);
            assert_eq!(hi.merge(lo).into_indices(), reference);
        });
    }

    /// On finite inputs, the K=2 instantiation agrees with the dedicated
    /// 2-objective [`pareto_front`] (whose index order it shares).
    #[test]
    fn front_k2_agrees_with_pareto_front_on_finite_inputs() {
        check(Config::default().cases(50).seed(7), |rng: &mut Rng| {
            let n = 2 + rng.index(40);
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| (rng.uniform(0.0, 4.0).round(), rng.uniform(0.0, 4.0).round()))
                .collect();
            let rows: Vec<[f64; 2]> = pts.iter().map(|&(a, b)| [a, b]).collect();
            assert_eq!(pareto_front_k(&rows), pareto_front(&pts));
            let streaming =
                FrontK::from_points(rows.iter().enumerate().map(|(i, &r)| (r, i)));
            assert_eq!(streaming.into_indices(), pareto_front(&pts));
        });
    }

    #[test]
    fn front_k_serialization_is_bit_exact() {
        let mut f: FrontK<3> = FrontK::new();
        f.push([f64::MIN_POSITIVE, 1e300, 2.0], 3);
        f.push([1e300, f64::MIN_POSITIVE, 1.0], 9);
        f.push([0.5, 0.25, 3.0], 4);
        let v = f.to_value();
        let back = FrontK::<3>::from_value(&v).unwrap();
        let key = |front: &FrontK<3>| {
            let mut rows: Vec<([u64; 3], usize)> = front
                .points()
                .iter()
                .map(|&(o, i)| ([o[0].to_bits(), o[1].to_bits(), o[2].to_bits()], i))
                .collect();
            rows.sort_unstable();
            rows
        };
        assert_eq!(key(&f), key(&back));
        // And through the JSON text layer.
        let text = v.to_json_string().unwrap();
        let reparsed =
            FrontK::<3>::from_value(&crate::config::parse_json(&text).unwrap()).unwrap();
        assert_eq!(reparsed.indices(), f.indices());
    }

    #[test]
    fn front_k_from_value_rejects_malformed_payloads() {
        use crate::config::parse_json;
        for text in [
            "{}",
            "[[1, 2, 3, 0]]",
            // A valid 2-objective triple is the wrong arity for K=3.
            "[[\"3ff0000000000000\", \"3ff0000000000000\", 0]]",
            "[[\"3ff0000000000000\", \"zz\", \"3ff0000000000000\", 0]]",
            "[[\"3ff0000000000000\", \"3ff0000000000000\", \"3ff0000000000000\", -1]]",
        ] {
            let v = parse_json(text).unwrap();
            assert!(FrontK::<3>::from_value(&v).is_err(), "{text}");
        }
    }

    #[test]
    fn streaming_front_from_value_rejects_malformed_payloads() {
        use crate::config::parse_json;
        for text in [
            "{}",
            "[[1, 2, 3]]",
            "[[\"3ff0000000000000\", \"zz\", 0]]",
            "[[\"3ff0000000000000\", \"3ff0000000000000\"]]",
            "[[\"3ff0000000000000\", \"3ff0000000000000\", -1]]",
        ] {
            let v = parse_json(text).unwrap();
            assert!(StreamingFront::from_value(&v).is_err(), "{text}");
        }
    }
}
