//! Reporting substrate: ASCII tables, CSV emission, log-log ASCII plots.
//!
//! Every figure bench prints both a machine-readable CSV block and a
//! human-readable table/plot, so `cargo bench` output alone documents the
//! reproduction (EXPERIMENTS.md embeds these).

pub mod plot;

pub use plot::AsciiPlot;

/// A simple right-padded ASCII table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column headers.
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Table { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header arity).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with aligned columns and a header rule.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cells[i], width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (headers + rows; cells containing commas are quoted).
    pub fn to_csv(&self) -> String {
        let quote = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self.headers.iter().map(|h| quote(h)).collect::<Vec<_>>().join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with engineering-style compactness for table cells.
pub fn sig(x: f64, digits: usize) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let mag = x.abs();
    if (1e-3..1e6).contains(&mag) {
        let decimals = (digits as i32 - 1 - mag.log10().floor() as i32).max(0) as usize;
        format!("{x:.decimals$}")
    } else {
        format!("{x:.prec$e}", prec = digits.saturating_sub(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["short", "1"]);
        t.row(vec!["a-much-longer-name", "22"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        // "value" column starts at the same offset in all rows.
        let col = lines[0].find("value").unwrap();
        assert_eq!(&lines[3][col..col + 2], "22");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        Table::new(vec!["a", "b"]).row(vec!["only-one"]);
    }

    #[test]
    fn csv_quotes_commas() {
        let mut t = Table::new(vec!["k"]);
        t.row(vec!["a,b"]);
        assert_eq!(t.to_csv(), "k\n\"a,b\"\n");
    }

    #[test]
    fn sig_formatting() {
        assert_eq!(sig(0.0, 3), "0");
        assert_eq!(sig(1234.5, 3), "1234");  // >= 1e3 keeps integer digits
        assert_eq!(sig(0.05, 2), "0.050");
        assert!(sig(1.3e9, 3).contains('e'));
    }
}
