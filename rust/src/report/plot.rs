//! ASCII log-log plots — the Figs. 2/3 visualization in terminal form.
//!
//! Renders scatter series (survey dots) and line series (model bounds) on
//! a shared log-log canvas with decade tick labels.

use crate::util::logspace::log10;

/// A plot series: points plus the glyph to draw them with.
#[derive(Clone, Debug)]
struct Series {
    label: String,
    glyph: char,
    points: Vec<(f64, f64)>,
}

/// An ASCII canvas for log-log scatter/line plots.
#[derive(Clone, Debug)]
pub struct AsciiPlot {
    title: String,
    x_label: String,
    y_label: String,
    width: usize,
    height: usize,
    series: Vec<Series>,
}

impl AsciiPlot {
    /// New plot with the given title and axis labels.
    pub fn new(title: &str, x_label: &str, y_label: &str) -> Self {
        AsciiPlot {
            title: title.to_string(),
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            width: 72,
            height: 22,
            series: Vec::new(),
        }
    }

    /// Set canvas size in characters.
    pub fn size(mut self, width: usize, height: usize) -> Self {
        assert!(width >= 20 && height >= 8);
        self.width = width;
        self.height = height;
        self
    }

    /// Add a series; `(x, y)` must be positive (log-log canvas).
    pub fn series(mut self, label: &str, glyph: char, points: Vec<(f64, f64)>) -> Self {
        self.series.push(Series { label: label.to_string(), glyph, points });
        self
    }

    /// Render the plot.
    pub fn render(&self) -> String {
        let pts: Vec<(f64, f64)> = self
            .series
            .iter()
            .flat_map(|s| s.points.iter().copied())
            .filter(|&(x, y)| x > 0.0 && y > 0.0)
            .collect();
        if pts.is_empty() {
            return format!("{} (no data)\n", self.title);
        }
        let (mut x0, mut x1) = (f64::MAX, f64::MIN);
        let (mut y0, mut y1) = (f64::MAX, f64::MIN);
        for &(x, y) in &pts {
            x0 = x0.min(log10(x));
            x1 = x1.max(log10(x));
            y0 = y0.min(log10(y));
            y1 = y1.max(log10(y));
        }
        // Pad degenerate ranges.
        if (x1 - x0).abs() < 1e-9 {
            x0 -= 0.5;
            x1 += 0.5;
        }
        if (y1 - y0).abs() < 1e-9 {
            y0 -= 0.5;
            y1 += 0.5;
        }

        let mut grid = vec![vec![' '; self.width]; self.height];
        for s in &self.series {
            for &(x, y) in &s.points {
                if x <= 0.0 || y <= 0.0 {
                    continue;
                }
                let cx = ((log10(x) - x0) / (x1 - x0) * (self.width - 1) as f64).round()
                    as usize;
                let cy = ((log10(y) - y0) / (y1 - y0) * (self.height - 1) as f64).round()
                    as usize;
                let row = self.height - 1 - cy.min(self.height - 1);
                let col = cx.min(self.width - 1);
                // Lines (drawn later in series order) win over scatter dots.
                grid[row][col] = s.glyph;
            }
        }

        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        let legend: Vec<String> = self
            .series
            .iter()
            .map(|s| format!("{} {}", s.glyph, s.label))
            .collect();
        out.push_str(&format!("  [{}]\n", legend.join("   ")));
        for (i, row) in grid.iter().enumerate() {
            let y_val = y1 - (y1 - y0) * i as f64 / (self.height - 1) as f64;
            let label = if i == 0 || i == self.height - 1 || i == self.height / 2 {
                format!("1e{y_val:>5.1}")
            } else {
                String::from("       ")
            };
            out.push_str(&format!("{label} |{}\n", row.iter().collect::<String>()));
        }
        out.push_str(&format!(
            "        +{}\n         1e{:<6.1}{}1e{:>6.1}  ({})\n",
            "-".repeat(self.width),
            x0,
            " ".repeat(self.width.saturating_sub(18)),
            x1,
            self.x_label,
        ));
        out.push_str(&format!("         y: {}\n", self.y_label));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_points_within_canvas() {
        let plot = AsciiPlot::new("t", "x", "y")
            .series("dots", '*', vec![(1e3, 1.0), (1e9, 100.0)]);
        let s = plot.render();
        assert!(s.contains('*'));
        assert!(s.contains("t\n"));
        assert!(s.contains("dots"));
    }

    #[test]
    fn empty_plot_does_not_panic() {
        let s = AsciiPlot::new("empty", "x", "y").render();
        assert!(s.contains("no data"));
    }

    #[test]
    fn degenerate_single_point() {
        let s = AsciiPlot::new("one", "x", "y").series("p", 'o', vec![(10.0, 10.0)]);
        assert!(s.render().contains('o'));
    }

    #[test]
    fn later_series_overdraw_earlier() {
        let plot = AsciiPlot::new("t", "x", "y")
            .series("a", 'a', vec![(10.0, 10.0), (100.0, 100.0)])
            .series("b", 'b', vec![(10.0, 10.0)]);
        let rendered = plot.render();
        // The shared coordinate shows 'b' (drawn later).
        assert!(rendered.contains('b'));
    }
}
