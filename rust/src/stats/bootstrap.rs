//! Bootstrap confidence intervals for fitted coefficients.
//!
//! Resample-with-replacement the observation set, refit, and report
//! percentile intervals. Used to quantify how sensitive the ADC-model
//! coefficients are to the survey sample (EXPERIMENTS.md reports these
//! alongside the point fits).

use crate::error::Result;
use crate::util::Rng;

/// A percentile confidence interval for one statistic.
#[derive(Clone, Copy, Debug)]
pub struct ConfidenceInterval {
    /// Lower percentile bound.
    pub lo: f64,
    /// Point estimate from the full sample.
    pub point: f64,
    /// Upper percentile bound.
    pub hi: f64,
}

impl ConfidenceInterval {
    /// Interval width.
    pub fn width(&self) -> f64 {
        self.hi - self.lo
    }

    /// Whether the interval contains `x`.
    pub fn contains(&self, x: f64) -> bool {
        (self.lo..=self.hi).contains(&x)
    }
}

/// Bootstrap percentile CIs for a vector-valued fit statistic.
///
/// `fit` maps a resampled index set (into the caller's data) to a vector of
/// statistics (e.g. regression coefficients); resamples that fail to fit
/// are skipped (up to half may fail before this errors).
pub fn bootstrap_ci<F>(
    n_obs: usize,
    n_resamples: usize,
    confidence: f64,
    seed: u64,
    fit: F,
) -> Result<Vec<ConfidenceInterval>>
where
    F: Fn(&[usize]) -> Result<Vec<f64>>,
{
    assert!(n_obs > 0 && n_resamples > 0);
    assert!((0.0..1.0).contains(&confidence));

    let identity: Vec<usize> = (0..n_obs).collect();
    let point = fit(&identity)?;
    let k = point.len();

    let mut rng = Rng::new(seed);
    let mut samples: Vec<Vec<f64>> = Vec::with_capacity(n_resamples);
    for _ in 0..n_resamples {
        let idx: Vec<usize> = (0..n_obs).map(|_| rng.index(n_obs)).collect();
        if let Ok(stat) = fit(&idx) {
            debug_assert_eq!(stat.len(), k);
            samples.push(stat);
        }
    }
    if samples.len() < n_resamples / 2 {
        return Err(crate::error::Error::Fit(format!(
            "bootstrap: only {}/{} resamples fit successfully",
            samples.len(),
            n_resamples
        )));
    }

    let alpha = (1.0 - confidence) / 2.0;
    let cis = (0..k)
        .map(|j| {
            let vals: Vec<f64> = samples.iter().map(|s| s[j]).collect();
            ConfidenceInterval {
                lo: crate::stats::quantile(&vals, alpha),
                point: point[j],
                hi: crate::stats::quantile(&vals, 1.0 - alpha),
            }
        })
        .collect();
    Ok(cis)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::ols::ols;
    use crate::util::Rng;

    #[test]
    fn ci_covers_true_slope() {
        let mut rng = Rng::new(9);
        let xs: Vec<Vec<f64>> = (0..300).map(|_| vec![rng.uniform(0.0, 10.0)]).collect();
        let y: Vec<f64> = xs
            .iter()
            .map(|r| 2.0 + 1.5 * r[0] + rng.normal(0.0, 0.5))
            .collect();

        let cis = bootstrap_ci(xs.len(), 200, 0.95, 77, |idx| {
            let bx: Vec<Vec<f64>> = idx.iter().map(|&i| xs[i].clone()).collect();
            let by: Vec<f64> = idx.iter().map(|&i| y[i]).collect();
            Ok(ols(&bx, &by)?.coefs)
        })
        .unwrap();

        assert_eq!(cis.len(), 2);
        assert!(cis[0].contains(2.0), "intercept CI {:?}", cis[0]);
        assert!(cis[1].contains(1.5), "slope CI {:?}", cis[1]);
        assert!(cis[1].width() < 0.2, "slope CI too wide: {:?}", cis[1]);
    }

    #[test]
    fn point_estimate_within_interval() {
        let data: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let cis = bootstrap_ci(data.len(), 100, 0.9, 1, |idx| {
            Ok(vec![idx.iter().map(|&i| data[i]).sum::<f64>() / idx.len() as f64])
        })
        .unwrap();
        assert!(cis[0].contains(cis[0].point));
    }
}
