//! Two-bound piecewise power-law fitting (paper §II-A).
//!
//! ADC energy is modeled as the max of two bounds, both linear in log10
//! space:
//!
//! ```text
//! log10 E = max( a0 + a1·ENOB + a2·t,                 // minimum-energy bound
//!                b0 + b1·ENOB + b2·t + b3·log10 f )   // tradeoff bound
//! ```
//!
//! Fitting assigns every survey point to the bound that dominates at its
//! covariates, fits each segment by OLS, and iterates to a fixed point
//! (a 1-D EM over segment membership). Both intercepts are then shifted
//! down to the `envelope_q` residual quantile so the fit is a *best-case*
//! lower envelope, matching the paper's "reasonable lower-bound" intent.

use crate::error::{Error, Result};
use crate::stats::ols::ols;
use crate::stats::quantile::envelope_shift;

/// One observation for the envelope fit (all values in log10 space except
/// `enob`).
#[derive(Clone, Copy, Debug)]
pub struct EnergyPoint {
    /// Effective number of bits.
    pub enob: f64,
    /// log10(tech_nm / 32).
    pub log_t: f64,
    /// log10(per-ADC throughput, converts/s).
    pub log_f: f64,
    /// log10(energy per convert, pJ).
    pub log_e: f64,
}

/// Fitted two-bound envelope.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TwoBoundFit {
    /// Minimum-energy bound `[a0, a1, a2]` (intercept, ENOB, tech).
    pub flat: [f64; 3],
    /// Tradeoff bound `[b0, b1, b2, b3]` (intercept, ENOB, tech, log10 f).
    pub trade: [f64; 4],
    /// Number of EM iterations used.
    pub iterations: usize,
    /// Fraction of points assigned to the tradeoff segment at convergence.
    pub trade_fraction: f64,
}

impl TwoBoundFit {
    /// log10 of the minimum-energy bound at (enob, log_t).
    pub fn log_flat(&self, enob: f64, log_t: f64) -> f64 {
        self.flat[0] + self.flat[1] * enob + self.flat[2] * log_t
    }

    /// log10 of the tradeoff bound at (enob, log_t, log_f).
    pub fn log_trade(&self, enob: f64, log_t: f64, log_f: f64) -> f64 {
        self.trade[0] + self.trade[1] * enob + self.trade[2] * log_t + self.trade[3] * log_f
    }

    /// log10 of the modeled (max-of-bounds) energy.
    pub fn log_energy(&self, enob: f64, log_t: f64, log_f: f64) -> f64 {
        self.log_flat(enob, log_t).max(self.log_trade(enob, log_t, log_f))
    }

    /// Crossover throughput (log10 converts/s) where the two bounds meet
    /// for a given (enob, log_t). `None` if the tradeoff slope is ~0.
    pub fn log_crossover(&self, enob: f64, log_t: f64) -> Option<f64> {
        if self.trade[3].abs() < 1e-9 {
            return None;
        }
        Some((self.log_flat(enob, log_t) - self.trade[0]
            - self.trade[1] * enob
            - self.trade[2] * log_t)
            / self.trade[3])
    }
}

/// Fit the two-bound envelope to survey points.
///
/// `envelope_q` is the residual quantile both intercepts are shifted down
/// to (0.05 ≈ best-case envelope; 0.5 ≈ central trend).
pub fn fit_two_bound_envelope(points: &[EnergyPoint], envelope_q: f64) -> Result<TwoBoundFit> {
    const MAX_ITERS: usize = 20;
    const MIN_SEGMENT: usize = 8;
    if points.len() < 2 * MIN_SEGMENT {
        return Err(Error::Fit(format!(
            "two-bound fit needs >= {} points, got {}",
            2 * MIN_SEGMENT,
            points.len()
        )));
    }

    // Initial split at the median log-throughput.
    let mut fs: Vec<f64> = points.iter().map(|p| p.log_f).collect();
    fs.sort_by(|a, b| a.total_cmp(b));
    let median_f = fs[fs.len() / 2];
    let mut in_trade: Vec<bool> = points.iter().map(|p| p.log_f > median_f).collect();

    let mut flat = [0.0; 3];
    let mut trade = [0.0; 4];
    let mut iterations = 0;

    for iter in 0..MAX_ITERS {
        iterations = iter + 1;

        let flat_pts: Vec<&EnergyPoint> = points
            .iter()
            .zip(&in_trade)
            .filter(|(_, &t)| !t)
            .map(|(p, _)| p)
            .collect();
        let trade_pts: Vec<&EnergyPoint> = points
            .iter()
            .zip(&in_trade)
            .filter(|(_, &t)| t)
            .map(|(p, _)| p)
            .collect();
        if flat_pts.len() < MIN_SEGMENT || trade_pts.len() < MIN_SEGMENT {
            return Err(Error::Fit(format!(
                "two-bound fit: degenerate segments ({} flat / {} trade)",
                flat_pts.len(),
                trade_pts.len()
            )));
        }

        let flat_fit = ols(
            &flat_pts.iter().map(|p| vec![p.enob, p.log_t]).collect::<Vec<_>>(),
            &flat_pts.iter().map(|p| p.log_e).collect::<Vec<_>>(),
        )?;
        let trade_fit = ols(
            &trade_pts
                .iter()
                .map(|p| vec![p.enob, p.log_t, p.log_f])
                .collect::<Vec<_>>(),
            &trade_pts.iter().map(|p| p.log_e).collect::<Vec<_>>(),
        )?;

        flat = [flat_fit.coefs[0], flat_fit.coefs[1], flat_fit.coefs[2]];
        trade = [
            trade_fit.coefs[0],
            trade_fit.coefs[1],
            trade_fit.coefs[2],
            trade_fit.coefs[3],
        ];

        // Reassign: a point belongs to the tradeoff segment when that bound
        // dominates at its covariates.
        let probe = TwoBoundFit { flat, trade, iterations, trade_fraction: 0.0 };
        let next: Vec<bool> = points
            .iter()
            .map(|p| probe.log_trade(p.enob, p.log_t, p.log_f) > probe.log_flat(p.enob, p.log_t))
            .collect();
        if next == in_trade {
            break;
        }
        in_trade = next;
    }

    // Envelope calibration: shift both intercepts so `envelope_q` of the
    // residuals against max(bounds) fall below the model.
    let probe = TwoBoundFit { flat, trade, iterations, trade_fraction: 0.0 };
    let residuals: Vec<f64> = points
        .iter()
        .map(|p| p.log_e - probe.log_energy(p.enob, p.log_t, p.log_f))
        .collect();
    let shift = envelope_shift(&residuals, envelope_q);
    flat[0] += shift;
    trade[0] += shift;

    let trade_fraction =
        in_trade.iter().filter(|&&t| t).count() as f64 / points.len() as f64;
    Ok(TwoBoundFit { flat, trade, iterations, trade_fraction })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Generate points from known ground-truth bounds plus positive scatter.
    fn synth(rng: &mut Rng, n: usize, flat: [f64; 3], trade: [f64; 4]) -> Vec<EnergyPoint> {
        (0..n)
            .map(|_| {
                let enob = rng.uniform(3.0, 13.0);
                let log_t = rng.uniform(-0.3, 1.0);
                let log_f = rng.uniform(4.0, 10.0);
                let truth = TwoBoundFit { flat, trade, iterations: 0, trade_fraction: 0.0 };
                let log_e =
                    truth.log_energy(enob, log_t, log_f) + rng.exponential(0.35);
                EnergyPoint { enob, log_t, log_f, log_e }
            })
            .collect()
    }

    const FLAT: [f64; 3] = [-2.301, 0.25, 1.0];
    const TRADE: [f64; 4] = [-14.301, 0.55, 1.0, 1.2];

    #[test]
    fn recovers_ground_truth_bounds() {
        let mut rng = Rng::new(42);
        let pts = synth(&mut rng, 2000, FLAT, TRADE);
        let fit = fit_two_bound_envelope(&pts, 0.05).unwrap();
        // Slopes recovered to ~10-15% despite the one-sided scatter.
        assert!((fit.flat[1] - FLAT[1]).abs() < 0.06, "a1={}", fit.flat[1]);
        assert!((fit.trade[3] - TRADE[3]).abs() < 0.25, "b3={}", fit.trade[3]);
        assert!((fit.trade[1] - TRADE[1]).abs() < 0.12, "b1={}", fit.trade[1]);
        // Envelope property: ~95% of points at/above model.
        let below = pts
            .iter()
            .filter(|p| p.log_e < fit.log_energy(p.enob, p.log_t, p.log_f))
            .count();
        let frac = below as f64 / pts.len() as f64;
        assert!(frac < 0.10, "below-envelope fraction {frac}");
    }

    #[test]
    fn crossover_decreases_with_enob() {
        let fit = TwoBoundFit { flat: FLAT, trade: TRADE, iterations: 0, trade_fraction: 0.0 };
        let c4 = fit.log_crossover(4.0, 0.0).unwrap();
        let c8 = fit.log_crossover(8.0, 0.0).unwrap();
        let c12 = fit.log_crossover(12.0, 0.0).unwrap();
        assert!(c4 > c8 && c8 > c12, "{c4} {c8} {c12}");
        assert!((c4 - 9.0).abs() < 1e-9); // ground truth anchor
    }

    #[test]
    fn max_of_bounds_is_continuous_at_crossover() {
        let fit = TwoBoundFit { flat: FLAT, trade: TRADE, iterations: 0, trade_fraction: 0.0 };
        let c = fit.log_crossover(8.0, 0.0).unwrap();
        let below = fit.log_energy(8.0, 0.0, c - 1e-9);
        let above = fit.log_energy(8.0, 0.0, c + 1e-9);
        assert!((below - above).abs() < 1e-6);
    }

    #[test]
    fn too_few_points_errors() {
        let pts: Vec<EnergyPoint> = Vec::new();
        assert!(fit_two_bound_envelope(&pts, 0.05).is_err());
    }
}
