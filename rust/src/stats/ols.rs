//! Multi-variable ordinary least squares via the normal equations.
//!
//! The fit problems in this crate are tiny (2–4 predictors, ~700 points),
//! so forming X'X and solving with partially-pivoted Gaussian elimination
//! is both adequate and dependency-free.

use crate::error::{Error, Result};

/// Result of an OLS fit `y ≈ X·β` (X includes an intercept column).
#[derive(Clone, Debug)]
pub struct OlsFit {
    /// Coefficients; `coefs[0]` is the intercept, followed by one slope per
    /// predictor in input order.
    pub coefs: Vec<f64>,
    /// Residuals `y_i - ŷ_i` in input order.
    pub residuals: Vec<f64>,
    /// Coefficient of determination.
    pub r2: f64,
}

impl OlsFit {
    /// Predict for a single row of predictors (without intercept entry).
    pub fn predict(&self, x: &[f64]) -> f64 {
        assert_eq!(x.len() + 1, self.coefs.len());
        self.coefs[0]
            + x.iter()
                .zip(&self.coefs[1..])
                .map(|(xi, b)| xi * b)
                .sum::<f64>()
    }
}

/// Solve a dense linear system `A·x = b` by Gaussian elimination with
/// partial pivoting. `a` is row-major `n x n`.
pub fn solve_linear(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Result<Vec<f64>> {
    let n = b.len();
    for (row, cols) in a.iter().enumerate() {
        if cols.len() != n {
            return Err(Error::Numeric(format!(
                "solve_linear: row {row} has {} cols, expected {n}",
                cols.len()
            )));
        }
    }
    for col in 0..n {
        // pivot
        let pivot = (col..n)
            .max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))
            .unwrap();
        if a[pivot][col].abs() < 1e-12 {
            return Err(Error::Fit("singular system in OLS solve".into()));
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        // eliminate below
        for row in col + 1..n {
            let factor = a[row][col] / a[col][col];
            if factor == 0.0 {
                continue;
            }
            for k in col..n {
                a[row][k] -= factor * a[col][k];
            }
            b[row] -= factor * b[col];
        }
    }
    // back-substitute
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for k in row + 1..n {
            acc -= a[row][k] * x[k];
        }
        x[row] = acc / a[row][row];
    }
    Ok(x)
}

/// Fit `y ≈ β0 + Σ βj·xj` by least squares.
///
/// `xs[i]` is the predictor row for observation `i` (all rows must share a
/// length), `y[i]` the response. Returns an error when the system is
/// under-determined or singular.
pub fn ols(xs: &[Vec<f64>], y: &[f64]) -> Result<OlsFit> {
    if xs.len() != y.len() {
        return Err(Error::Fit(format!(
            "ols: {} predictor rows vs {} responses",
            xs.len(),
            y.len()
        )));
    }
    let n = xs.len();
    let p = xs.first().map_or(0, |r| r.len()) + 1; // + intercept
    if n < p {
        return Err(Error::Fit(format!("ols: {n} points for {p} coefficients")));
    }

    // Normal equations: (X'X) β = X'y with X = [1 | xs].
    let mut xtx = vec![vec![0.0; p]; p];
    let mut xty = vec![0.0; p];
    for (row, &yi) in xs.iter().zip(y) {
        if row.len() + 1 != p {
            return Err(Error::Fit("ols: ragged predictor rows".into()));
        }
        // augmented row [1, x0, x1, ...]
        let aug = |j: usize| if j == 0 { 1.0 } else { row[j - 1] };
        for i in 0..p {
            xty[i] += aug(i) * yi;
            for j in i..p {
                xtx[i][j] += aug(i) * aug(j);
            }
        }
    }
    for i in 0..p {
        for j in 0..i {
            xtx[i][j] = xtx[j][i];
        }
    }

    let coefs = solve_linear(xtx, xty)?;

    let fit = OlsFit { coefs, residuals: Vec::new(), r2: 0.0 };
    let residuals: Vec<f64> = xs
        .iter()
        .zip(y)
        .map(|(row, &yi)| yi - fit.predict(row))
        .collect();
    let mean_y = y.iter().sum::<f64>() / n as f64;
    let ss_tot: f64 = y.iter().map(|&yi| (yi - mean_y).powi(2)).sum();
    let ss_res: f64 = residuals.iter().map(|r| r * r).sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };

    Ok(OlsFit { residuals, r2, ..fit })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn recovers_exact_linear_relation() {
        // y = 2 + 3a - 5b, no noise.
        let mut rng = Rng::new(1);
        let xs: Vec<Vec<f64>> = (0..50)
            .map(|_| vec![rng.uniform(-10.0, 10.0), rng.uniform(-10.0, 10.0)])
            .collect();
        let y: Vec<f64> = xs.iter().map(|r| 2.0 + 3.0 * r[0] - 5.0 * r[1]).collect();
        let fit = ols(&xs, &y).unwrap();
        assert!((fit.coefs[0] - 2.0).abs() < 1e-9);
        assert!((fit.coefs[1] - 3.0).abs() < 1e-9);
        assert!((fit.coefs[2] + 5.0).abs() < 1e-9);
        assert!((fit.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noisy_fit_is_close_and_r2_below_one() {
        let mut rng = Rng::new(2);
        let xs: Vec<Vec<f64>> = (0..500).map(|_| vec![rng.uniform(0.0, 1.0)]).collect();
        let y: Vec<f64> = xs
            .iter()
            .map(|r| 1.0 + 4.0 * r[0] + rng.normal(0.0, 0.1))
            .collect();
        let fit = ols(&xs, &y).unwrap();
        assert!((fit.coefs[0] - 1.0).abs() < 0.05);
        assert!((fit.coefs[1] - 4.0).abs() < 0.1);
        assert!(fit.r2 > 0.9 && fit.r2 < 1.0);
    }

    #[test]
    fn under_determined_errors() {
        let xs = vec![vec![1.0, 2.0]];
        let y = vec![3.0];
        assert!(ols(&xs, &y).is_err());
    }

    #[test]
    fn singular_errors() {
        // Duplicate predictor column -> singular normal equations.
        let xs: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64, i as f64]).collect();
        let y: Vec<f64> = (0..20).map(|i| i as f64).collect();
        assert!(ols(&xs, &y).is_err());
    }

    #[test]
    fn predict_matches_training_points_when_exact() {
        let xs: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..10).map(|i| 7.0 - 2.0 * i as f64).collect();
        let fit = ols(&xs, &y).unwrap();
        for (row, &yi) in xs.iter().zip(&y) {
            assert!((fit.predict(row) - yi).abs() < 1e-9);
        }
    }

    #[test]
    fn solve_linear_identity() {
        let a = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let x = solve_linear(a, vec![3.0, -4.0]).unwrap();
        assert_eq!(x, vec![3.0, -4.0]);
    }
}
