//! Quantiles and lower-envelope calibration.
//!
//! The paper's model calibrates bounds against the survey: the energy
//! bounds are *best-case* (lower envelope of the published-ADC cloud) and
//! the area model is "optimistically reduced to match the lowest-area 10%
//! of ADCs". Both are intercept shifts by a residual quantile, implemented
//! here as [`envelope_shift`].

/// Linear-interpolated quantile of `xs` at `q ∈ [0, 1]`.
///
/// Matches numpy's default (linear) method. Panics on empty input or
/// out-of-range `q`.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q), "quantile q={q} out of [0,1]");
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median (0.5 quantile).
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Intercept shift that moves a fitted central-trend line down (or up) so
/// that fraction `q` of the residuals lie below it.
///
/// Given OLS residuals `r_i = y_i - ŷ_i`, adding `envelope_shift(r, q)` to
/// the fit's intercept makes the line pass through the `q`-quantile of the
/// point cloud — `q = 0.05` turns a central fit into a best-case
/// lower envelope, `q = 0.10` reproduces the paper's lowest-area-10%
/// calibration.
pub fn envelope_shift(residuals: &[f64], q: f64) -> f64 {
    quantile(residuals, q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_endpoints_and_median() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(median(&xs), 3.0);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 1.0];
        assert!((quantile(&xs, 0.25) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn envelope_shift_puts_q_fraction_below() {
        // residuals uniform over [0, 99]
        let residuals: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let shift = envelope_shift(&residuals, 0.1);
        let below = residuals.iter().filter(|&&r| r < shift).count();
        assert!((9..=10).contains(&below), "below={below}");
    }

    #[test]
    #[should_panic]
    fn quantile_empty_panics() {
        quantile(&[], 0.5);
    }
}
