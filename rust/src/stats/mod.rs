//! Regression substrate for the survey-fit pipeline (paper Fig. 1).
//!
//! Everything the ADC model fit needs: multi-variable ordinary least
//! squares in log space ([`mod@ols`]), quantile utilities and lower-envelope
//! calibration ([`mod@quantile`]), correlation metrics ([`corr`]), the
//! two-bound piecewise power-law fit ([`piecewise`]), and bootstrap
//! confidence intervals ([`bootstrap`]).

pub mod bootstrap;
pub mod corr;
pub mod ols;
pub mod piecewise;
pub mod quantile;

pub use bootstrap::bootstrap_ci;
pub use corr::{pearson_r, r_squared, rmse};
pub use ols::{OlsFit, ols};
pub use piecewise::{TwoBoundFit, fit_two_bound_envelope};
pub use quantile::{envelope_shift, quantile};
