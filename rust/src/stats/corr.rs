//! Correlation / goodness-of-fit metrics.
//!
//! The paper reports the Pearson correlation coefficient `r` of the area
//! regression improving from 0.66 (ENOB predictor) to 0.75 (energy
//! predictor); `bench area_corr` reproduces that comparison with these
//! routines.

/// Pearson correlation coefficient between two equal-length slices.
pub fn pearson_r(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "pearson_r: length mismatch");
    assert!(x.len() >= 2, "pearson_r: need at least 2 points");
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&xi, &yi) in x.iter().zip(y) {
        let dx = xi - mx;
        let dy = yi - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Coefficient of determination of predictions vs observations.
pub fn r_squared(observed: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(observed.len(), predicted.len());
    let n = observed.len() as f64;
    let mean = observed.iter().sum::<f64>() / n;
    let ss_tot: f64 = observed.iter().map(|&o| (o - mean).powi(2)).sum();
    let ss_res: f64 = observed
        .iter()
        .zip(predicted)
        .map(|(&o, &p)| (o - p).powi(2))
        .sum();
    if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot }
}

/// Root-mean-square error of predictions vs observations.
pub fn rmse(observed: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(observed.len(), predicted.len());
    assert!(!observed.is_empty());
    let ss: f64 = observed
        .iter()
        .zip(predicted)
        .map(|(&o, &p)| (o - p).powi(2))
        .sum();
    (ss / observed.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn perfect_positive_and_negative() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y: Vec<f64> = x.iter().map(|v| 2.0 * v + 1.0).collect();
        assert!((pearson_r(&x, &y) - 1.0).abs() < 1e-12);
        let yneg: Vec<f64> = x.iter().map(|v| -v).collect();
        assert!((pearson_r(&x, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn independent_data_near_zero() {
        let mut rng = Rng::new(5);
        let x: Vec<f64> = (0..20_000).map(|_| rng.f64()).collect();
        let y: Vec<f64> = (0..20_000).map(|_| rng.f64()).collect();
        assert!(pearson_r(&x, &y).abs() < 0.03);
    }

    #[test]
    fn constant_input_gives_zero() {
        let x = [1.0, 1.0, 1.0];
        let y = [1.0, 2.0, 3.0];
        assert_eq!(pearson_r(&x, &y), 0.0);
    }

    #[test]
    fn r2_and_rmse_for_exact_prediction() {
        let o = [1.0, 2.0, 3.0];
        assert_eq!(r_squared(&o, &o), 1.0);
        assert_eq!(rmse(&o, &o), 0.0);
    }

    #[test]
    fn rmse_known_value() {
        let o = [0.0, 0.0];
        let p = [3.0, 4.0];
        assert!((rmse(&o, &p) - (12.5f64).sqrt()).abs() < 1e-12);
    }
}
