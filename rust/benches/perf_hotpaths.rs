//! Bench: the whole-stack hot paths (EXPERIMENTS.md §Perf).
//!
//! L3 native: single-point eval, the sweep drivers (serial eval, pooled
//! eval, invariant-hoisted prepared kernel, ULP-bounded fast tier),
//! streaming rollups, mapper, rollup. L3↔PJRT: artifact batch evaluation
//! and marshalling overhead.
//!
//! Writes the machine-readable perf trajectory to `BENCH_sweep.json`
//! (schema in `bench_util::JsonReport`; `CIMDSE_BENCH_OUT` overrides the
//! path). `ci.sh` runs this bench in `CIMDSE_BENCH_QUICK=1` mode and
//! fails if the artifact is missing or malformed.
//!
//! Run with `cargo bench --bench perf_hotpaths`.

use cimdse::adc::{AdcModel, AdcQuery};
use cimdse::arch::raella::{RaellaVariant, raella};
use cimdse::bench_util::{Bench, JsonReport, quick, scale};
use cimdse::dse::{
    NativeEvaluator, SweepSpec, SweepTier, run_sweep, run_sweep_prepared, run_sweep_prepared_tier,
    sweep_min_eap,
};
use cimdse::energy::layer_energy;
use cimdse::exec::{Pool, default_workers};
use cimdse::mapper::map_layer;
use cimdse::runtime::{AdcModelEngine, Manifest};
use cimdse::workload::resnet18::large_tensor_layer;

fn main() {
    let model = AdcModel::default();
    let bench = Bench::auto();
    let mut report = JsonReport::new("sweep");
    if quick() {
        println!("(CIMDSE_BENCH_QUICK: reduced budgets and grids)\n");
    }
    // Spin the pool up outside the timed regions.
    let _ = Pool::global().workers();

    // --- L3 native hot paths ------------------------------------------------
    let q = AdcQuery { enob: 7.0, total_throughput: 1.3e9, tech_nm: 32.0, n_adcs: 8 };
    let s = bench.run("adc model: single eval", || {
        std::hint::black_box(model.eval(std::hint::black_box(&q)));
    });
    report.case("single eval", &s, 1);

    let spec = SweepSpec::dense(18); // 18*18*4*6 = 7776 points
    let n_points = spec.len();
    println!("sweep size: {n_points} design points");

    let serial = NativeEvaluator::serial(model);
    let s_serial = bench.run("sweep dense18: eval serial", || {
        std::hint::black_box(run_sweep(&spec, &serial).unwrap());
    });
    report.case("dense18 eval serial", &s_serial, n_points);

    let threaded = NativeEvaluator::new(model);
    let s_pool = bench.run(
        &format!("sweep dense18: eval pooled ({} workers)", default_workers()),
        || {
            std::hint::black_box(run_sweep(&spec, &threaded).unwrap());
        },
    );
    report.case("dense18 eval pooled", &s_pool, n_points);

    let s_prep = bench.run("sweep dense18: prepared serial", || {
        std::hint::black_box(run_sweep_prepared(&spec, &model, 1).unwrap());
    });
    report.case("dense18 prepared serial", &s_prep, n_points);

    let s_prep_pool = bench.run("sweep dense18: prepared pooled", || {
        std::hint::black_box(run_sweep_prepared(&spec, &model, default_workers()).unwrap());
    });
    report.case("dense18 prepared pooled", &s_prep_pool, n_points);

    println!("fast tier backend: {}", cimdse::util::fastmath::fast_backend());
    let s_fast = bench.run("sweep dense18: fast serial", || {
        std::hint::black_box(run_sweep_prepared_tier(&spec, &model, 1, SweepTier::Fast).unwrap());
    });
    report.case("dense18 fast serial", &s_fast, n_points);
    let s_fast_pool = bench.run("sweep dense18: fast pooled", || {
        std::hint::black_box(
            run_sweep_prepared_tier(&spec, &model, default_workers(), SweepTier::Fast).unwrap(),
        );
    });
    report.case("dense18 fast pooled", &s_fast_pool, n_points);

    let speedup_prepared = s_serial.median_s / s_prep.median_s;
    let pool_scaling = s_prep.median_s / s_prep_pool.median_s;
    println!(
        "  -> dense18 throughput: eval serial {:.2} Mpts/s, prepared serial {:.2} Mpts/s \
         ({speedup_prepared:.1}x), prepared pooled {:.2} Mpts/s ({pool_scaling:.1}x over \
         serial on {} workers)",
        n_points as f64 / s_serial.median_s / 1e6,
        n_points as f64 / s_prep.median_s / 1e6,
        n_points as f64 / s_prep_pool.median_s / 1e6,
        default_workers(),
    );
    let speedup_fast = s_prep.median_s / s_fast.median_s;
    println!(
        "  -> dense18 fast tier ({}): {:.2} Mpts/s serial ({speedup_fast:.2}x over prepared \
         scalar), {:.2} Mpts/s pooled",
        cimdse::util::fastmath::fast_backend(),
        n_points as f64 / s_fast.median_s / 1e6,
        n_points as f64 / s_fast_pool.median_s / 1e6,
    );
    report.metric("speedup_prepared_vs_serial_dense18", speedup_prepared);
    report.metric("pool_scaling_prepared_dense18", pool_scaling);
    report.metric("speedup_pooled_vs_serial_eval_dense18", s_serial.median_s / s_pool.median_s);
    report.metric("speedup_fast_vs_prepared_dense18", speedup_fast);
    // Correctness pin: the prepared kernel must be bit-identical to the
    // eval path before any of its timings mean anything.
    let baseline = run_sweep(&spec, &serial).unwrap();
    let prepared_out = run_sweep_prepared(&spec, &model, 1).unwrap();
    assert_eq!(baseline.len(), prepared_out.len());
    for (a, b) in baseline.iter().zip(&prepared_out) {
        assert_eq!(a.query, b.query);
        assert_eq!(a.metrics.to_bits(), b.metrics.to_bits());
    }
    println!("  ok: prepared kernel bit-identical to AdcModel::eval over dense(18)");
    // Fast tier pin: every metric within the documented ULP envelope of
    // the exact kernel, and independent of the worker count.
    let fast_out = run_sweep_prepared_tier(&spec, &model, 1, SweepTier::Fast).unwrap();
    let fast_pool_out =
        run_sweep_prepared_tier(&spec, &model, default_workers(), SweepTier::Fast).unwrap();
    let mut worst_ulp = 0u64;
    for ((exact, fast), fp) in prepared_out.iter().zip(&fast_out).zip(&fast_pool_out) {
        assert_eq!(exact.query, fast.query);
        assert_eq!(fast.metrics.to_bits(), fp.metrics.to_bits());
        for (a, b) in exact.metrics.to_bits().iter().zip(fast.metrics.to_bits()) {
            let d = cimdse::util::fastmath::ulp_distance(f64::from_bits(*a), f64::from_bits(b));
            worst_ulp = worst_ulp.max(d);
        }
    }
    assert!(
        worst_ulp <= cimdse::util::fastmath::MAX_ULP,
        "fast tier drifted to {worst_ulp} ULP (bound {})",
        cimdse::util::fastmath::MAX_ULP
    );
    println!(
        "  ok: fast tier within {worst_ulp} ULP of exact over dense(18) (bound {}), \
         worker-independent",
        cimdse::util::fastmath::MAX_ULP
    );
    // Perf ratios are recorded in BENCH_sweep.json for trend tooling, not
    // hard-asserted: a noisy CI runner must not fail the build over them.
    if speedup_prepared <= 1.1 {
        println!(
            "  WARNING: prepared kernel only {speedup_prepared:.2}x over serial eval \
             (expected well above 1.1x; noisy machine or perf regression?)"
        );
    }

    // dense(40) tier: 40*40*4*6 = 38,400 points.
    let spec40 = SweepSpec::dense(40);
    let n40 = spec40.len();
    let s40_serial = bench.run("sweep dense40: prepared serial", || {
        std::hint::black_box(run_sweep_prepared(&spec40, &model, 1).unwrap());
    });
    report.case("dense40 prepared serial", &s40_serial, n40);
    let s40_pool = bench.run("sweep dense40: prepared pooled", || {
        std::hint::black_box(run_sweep_prepared(&spec40, &model, default_workers()).unwrap());
    });
    report.case("dense40 prepared pooled", &s40_pool, n40);
    let s40_fold = bench.run("sweep dense40: streaming min-EAP fold", || {
        std::hint::black_box(sweep_min_eap(&spec40, &model, default_workers()).unwrap());
    });
    report.case("dense40 streaming fold", &s40_fold, n40);
    report.metric("pool_scaling_prepared_dense40", s40_serial.median_s / s40_pool.median_s);

    // Streaming scale demo: a grid too big to want materialized
    // (~1.5M points full, ~0.24M quick) rolled up to its min-EAP point
    // with only chunk-sized buffers live. One-shot timing: the point is
    // that it completes without a query vector, not a tight median.
    let big = SweepSpec::dense(scale(250, 100));
    let n_big = big.len();
    let t0 = std::time::Instant::now();
    let best = sweep_min_eap(&big, &model, default_workers()).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "streaming sweep: {n_big} points -> min-EAP {} (ENOB {:.1}, {} ADCs) in {:.3} s \
         ({:.2} Mpts/s), no materialized query vector",
        best.metrics.energy_pj_per_convert * best.metrics.total_area_um2,
        best.query.enob,
        best.query.n_adcs,
        dt,
        n_big as f64 / dt / 1e6
    );
    report.metric("streaming_points", n_big as f64);
    report.metric("streaming_elapsed_s", dt);
    report.metric("streaming_mpts_per_s", n_big as f64 / dt / 1e6);

    // --- mapper / rollup ----------------------------------------------------
    let arch = raella(RaellaVariant::Medium);
    let layer = large_tensor_layer();
    let s_map = bench.run("mapper: map_layer", || {
        std::hint::black_box(map_layer(&arch, &layer).unwrap());
    });
    report.case("map_layer", &s_map, 1);
    let s_roll = bench.run("rollup: layer_energy", || {
        std::hint::black_box(layer_energy(&arch, &model, &layer).unwrap());
    });
    report.case("layer_energy", &s_roll, 1);

    // --- PJRT path ----------------------------------------------------------
    match Manifest::locate().and_then(|m| AdcModelEngine::load(&m)) {
        Ok(engine) => {
            let queries = spec.points();
            let batch = engine.batch_size();
            let full: Vec<AdcQuery> = queries.iter().cycle().take(batch).copied().collect();
            let slow = Bench::auto_slow();
            let st = slow.run("pjrt: one full batch (batch_size pts)", || {
                std::hint::black_box(engine.eval(&full, &model.coefs).unwrap());
            });
            report.case("pjrt full batch", &st, batch);
            println!(
                "  -> pjrt throughput: {:.2} Mpts/s",
                batch as f64 / st.median_s / 1e6
            );
            let sweep16k: Vec<AdcQuery> =
                queries.iter().cycle().take(4 * batch).copied().collect();
            slow.run("pjrt: 4-batch sweep (4x batch)", || {
                std::hint::black_box(engine.eval(&sweep16k, &model.coefs).unwrap());
            });
            // Marshalling overhead proxy: tiny batch pays full padding cost.
            slow.run("pjrt: 1-point call (padded to batch)", || {
                std::hint::black_box(engine.eval(&full[..1], &model.coefs).unwrap());
            });
        }
        Err(e) => println!("pjrt benches skipped: {e}"),
    }

    let path = report.write().expect("writing bench report");
    println!("\nwrote perf trajectory to {path}");
}
