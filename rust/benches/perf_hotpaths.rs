//! Bench: the whole-stack hot paths (EXPERIMENTS.md §Perf).
//!
//! L3 native: single-point eval, threaded sweeps, mapper, rollup.
//! L3↔PJRT: artifact batch evaluation and marshalling overhead.
//!
//! Run with `cargo bench --bench perf_hotpaths`.

use cimdse::adc::{AdcModel, AdcQuery};
use cimdse::bench_util::Bench;
use cimdse::dse::{Evaluator, NativeEvaluator, SweepSpec};
use cimdse::energy::layer_energy;
use cimdse::exec::default_workers;
use cimdse::mapper::map_layer;
use cimdse::arch::raella::{RaellaVariant, raella};
use cimdse::runtime::{AdcModelEngine, Manifest};
use cimdse::workload::resnet18::large_tensor_layer;

fn main() {
    let model = AdcModel::default();
    let bench = Bench::default();

    // --- L3 native hot paths ------------------------------------------------
    let q = AdcQuery { enob: 7.0, total_throughput: 1.3e9, tech_nm: 32.0, n_adcs: 8 };
    bench.run("adc model: single eval", || {
        std::hint::black_box(model.eval(std::hint::black_box(&q)));
    });

    let spec = SweepSpec::dense(18); // 18*18*4*6 = 7776 points
    let queries = spec.points();
    println!("sweep size: {} design points", queries.len());

    let serial = NativeEvaluator::serial(model);
    let s = bench.run("sweep: native serial", || {
        std::hint::black_box(serial.eval(&queries).unwrap());
    });
    let threaded = NativeEvaluator::new(model);
    let p = bench.run(
        &format!("sweep: native {} workers", default_workers()),
        || {
            std::hint::black_box(threaded.eval(&queries).unwrap());
        },
    );
    println!(
        "  -> native sweep throughput: serial {:.2} Mpts/s, threaded {:.2} Mpts/s ({:.1}x)",
        queries.len() as f64 / s.median_s / 1e6,
        queries.len() as f64 / p.median_s / 1e6,
        s.median_s / p.median_s
    );

    let arch = raella(RaellaVariant::Medium);
    let layer = large_tensor_layer();
    bench.run("mapper: map_layer", || {
        std::hint::black_box(map_layer(&arch, &layer).unwrap());
    });
    bench.run("rollup: layer_energy", || {
        std::hint::black_box(layer_energy(&arch, &model, &layer).unwrap());
    });

    // --- PJRT path ------------------------------------------------------------
    match Manifest::locate().and_then(|m| AdcModelEngine::load(&m)) {
        Ok(engine) => {
            let batch = engine.batch_size();
            let full: Vec<AdcQuery> = queries.iter().cycle().take(batch).copied().collect();
            let slow = Bench::slow();
            let st = slow.run("pjrt: one full batch (batch_size pts)", || {
                std::hint::black_box(engine.eval(&full, &model.coefs).unwrap());
            });
            println!(
                "  -> pjrt throughput: {:.2} Mpts/s",
                batch as f64 / st.median_s / 1e6
            );
            let sweep16k: Vec<AdcQuery> =
                queries.iter().cycle().take(4 * batch).copied().collect();
            slow.run("pjrt: 4-batch sweep (4x batch)", || {
                std::hint::black_box(engine.eval(&sweep16k, &model.coefs).unwrap());
            });
            // Marshalling overhead proxy: tiny batch pays full padding cost.
            slow.run("pjrt: 1-point call (padded to batch)", || {
                std::hint::black_box(engine.eval(&full[..1], &model.coefs).unwrap());
            });
        }
        Err(e) => println!("pjrt benches skipped: {e}"),
    }
}
