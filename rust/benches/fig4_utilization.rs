//! Bench: regenerate the paper's **Fig. 4** — full-accelerator energy for
//! RAELLA S/M/L/XL across layer groups of varying utilization — assert
//! the paper's three claims, and time the mapping+rollup pipeline.
//!
//! Run with `cargo bench --bench fig4_utilization`.

use cimdse::adc::{AdcModel, fit_model};
use cimdse::arch::raella::{RaellaVariant, raella};
use cimdse::bench_util::Bench;
use cimdse::dse::figures;
use cimdse::energy::layer_energy;
use cimdse::mapper::map_layer;
use cimdse::survey::generator::{SurveyConfig, generate_survey};
use cimdse::workload::resnet18::{large_tensor_layer, resnet18};

fn main() {
    let survey = generate_survey(&SurveyConfig::default());
    let model = AdcModel::new(fit_model(&survey).unwrap().coefs);

    let rows = figures::fig4(&model).unwrap();
    println!("Fig. 4: energy for varying utilization and analog sum size");
    println!("{}", figures::render_fig4(&rows).render());
    let t = figures::render_fig4(&rows);
    println!("CSV:\n{}", t.to_csv());

    // The paper's §III-A claims, asserted on the regenerated data:
    let get = |g: &str, v: &str| rows.iter().find(|r| r.group == g && r.variant == v).unwrap();
    // (1) large-tensor layer: summing more values reduces ADC energy.
    assert!(get("large-tensor", "XL").adc_pj < get("large-tensor", "L").adc_pj);
    assert!(get("large-tensor", "L").adc_pj < get("large-tensor", "M").adc_pj);
    assert!(get("large-tensor", "M").adc_pj < get("large-tensor", "S").adc_pj);
    println!("claim 1 ok: large-tensor ADC energy falls monotonically S -> XL");
    // (2) small-tensor layer: higher-ENOB ADCs consume more energy.
    assert!(get("small-tensor", "S").total_pj < get("small-tensor", "M").total_pj);
    assert!(get("small-tensor", "M").total_pj < get("small-tensor", "L").total_pj);
    assert!(get("small-tensor", "L").total_pj < get("small-tensor", "XL").total_pj);
    println!("claim 2 ok: small-tensor total energy rises monotonically S -> XL");
    // (3) over all layers, M and L balance the two effects.
    let mut all: Vec<_> = rows.iter().filter(|r| r.group == "all-layers").collect();
    all.sort_by(|a, b| a.total_pj.total_cmp(&b.total_pj));
    assert!(matches!(all[0].variant, "M" | "L"));
    assert!(matches!(all[1].variant, "M" | "L"));
    println!("claim 3 ok: best two overall variants are {{{}, {}}}\n", all[0].variant, all[1].variant);

    // --- timing (CIMDSE_BENCH_QUICK shrinks the budgets) --------------------
    let bench = Bench::auto();
    let net = resnet18();
    let arch = raella(RaellaVariant::Medium);
    let layer = large_tensor_layer();
    bench.run("fig4: map one layer", || {
        std::hint::black_box(map_layer(&arch, &layer).unwrap());
    });
    bench.run("fig4: map+price one layer", || {
        std::hint::black_box(layer_energy(&arch, &model, &layer).unwrap());
    });
    bench.run("fig4: all 21 layers x 4 variants", || {
        for variant in RaellaVariant::ALL {
            let arch = raella(variant);
            for l in &net.layers {
                std::hint::black_box(layer_energy(&arch, &model, l).unwrap());
            }
        }
    });
    bench.run("fig4: full figure", || {
        std::hint::black_box(figures::fig4(&model).unwrap());
    });
}
