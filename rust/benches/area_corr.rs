//! Bench: reproduce the paper's §II-B regression comparison — replacing
//! ENOB with energy in the area model improves the correlation
//! coefficient (paper: r 0.66 → 0.75) — with bootstrap CIs, plus fit
//! timing.
//!
//! Run with `cargo bench --bench area_corr`.

use cimdse::adc::fit::{FitReport, fit_model};
use cimdse::bench_util::{Bench, scale};
use cimdse::report::Table;
use cimdse::stats::bootstrap_ci;
use cimdse::stats::ols::ols;
use cimdse::survey::generator::{SurveyConfig, generate_survey};
use cimdse::util::logspace::log10;

fn main() {
    let survey = generate_survey(&SurveyConfig::default());
    let report: FitReport = fit_model(&survey).unwrap();

    let mut t = Table::new(vec!["area predictor set", "pearson r", "paper"]);
    t.row(vec![
        "tech + throughput + ENOB (prior work)".to_string(),
        format!("{:.3}", report.area_r_enob),
        "0.66".to_string(),
    ]);
    t.row(vec![
        "tech + throughput + energy (this model)".to_string(),
        format!("{:.3}", report.area_r_energy),
        "0.75".to_string(),
    ]);
    println!("§II-B area-regression correlation comparison:\n{}", t.render());
    assert!(report.area_r_energy > report.area_r_enob);
    println!(
        "ok: energy predictor improves r by {:+.3} (paper: +0.09)\n",
        report.area_r_energy - report.area_r_enob
    );

    // Bootstrap CIs on the Eq. 1 exponents (tech, throughput, energy).
    let xs: Vec<Vec<f64>> = survey
        .records
        .iter()
        .map(|r| vec![r.log_tech_ratio(), log10(r.throughput), log10(r.energy_pj)])
        .collect();
    let ys: Vec<f64> = survey.records.iter().map(|r| log10(r.area_um2)).collect();
    // CIMDSE_BENCH_QUICK: fewer bootstrap resamples.
    let cis = bootstrap_ci(xs.len(), scale(300, 80), 0.95, 7, |idx| {
        let bx: Vec<Vec<f64>> = idx.iter().map(|&i| xs[i].clone()).collect();
        let by: Vec<f64> = idx.iter().map(|&i| ys[i]).collect();
        Ok(ols(&bx, &by)?.coefs)
    })
    .unwrap();
    let mut t = Table::new(vec!["Eq.1 term", "point", "95% CI", "paper value"]);
    let names = ["intercept", "Tech exponent", "Throughput exponent", "Energy exponent"];
    let paper = ["-", "1.0", "0.2", "0.3"];
    for (i, name) in names.iter().enumerate() {
        t.row(vec![
            name.to_string(),
            format!("{:+.3}", cis[i].point),
            format!("[{:+.3}, {:+.3}]", cis[i].lo, cis[i].hi),
            paper[i].to_string(),
        ]);
    }
    println!("bootstrap CIs for the Eq. 1 regression:\n{}", t.render());

    // --- timing -------------------------------------------------------------
    let bench = Bench::auto();
    bench.run("area regression (700 pts, 3 predictors)", || {
        std::hint::black_box(ols(&xs, &ys).unwrap());
    });
    bench.run("full model fit (energy envelope + area)", || {
        std::hint::black_box(fit_model(&survey).unwrap());
    });
}
