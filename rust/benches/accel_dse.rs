//! Bench: accelerator-level DSE (§I claim 3 — "explore CiM accelerator
//! designs using different ADCs") across three workloads with different
//! utilization profiles, reporting the Pareto-optimal (sum size, ENOB,
//! n_adcs) configurations per workload, plus sweep timing.
//!
//! Run with `cargo bench --bench accel_dse`.

use cimdse::adc::{AdcModel, fit_model};
use cimdse::bench_util::Bench;
use cimdse::dse::accel::{AccelSweepSpec, accel_pareto, run_accel_sweep};
use cimdse::exec::default_workers;
use cimdse::report::Table;
use cimdse::survey::generator::{SurveyConfig, generate_survey};
use cimdse::util::units::{fmt_area_um2, fmt_energy_pj};
use cimdse::workload::{lenet, resnet18, vgg16};

fn main() {
    let survey = generate_survey(&SurveyConfig::default());
    let model = AdcModel::new(fit_model(&survey).unwrap().coefs);
    let spec = AccelSweepSpec::default();
    println!("{} fidelity-feasible candidate architectures per workload\n", spec.candidates().len());

    let mut best_sum_sizes = Vec::new();
    for workload in [lenet(), resnet18(), vgg16()] {
        let points = run_accel_sweep(&spec, &model, &workload, default_workers()).unwrap();
        let front = accel_pareto(&points);
        let best = points
            .iter()
            .min_by(|a, b| a.eap.total_cmp(&b.eap))
            .unwrap();
        best_sum_sizes.push((workload.name.clone(), best.arch.sum_size));

        let mut t = Table::new(vec!["config", "energy", "area", "ADC E%", "EAP rank"]);
        let mut on_front: Vec<_> = front.iter().map(|&i| &points[i]).collect();
        on_front.sort_by(|a, b| a.eap.total_cmp(&b.eap));
        for (rank, p) in on_front.iter().take(8).enumerate() {
            t.row(vec![
                p.arch.name.clone(),
                fmt_energy_pj(p.energy_pj),
                fmt_area_um2(p.area_um2),
                format!("{:.0}%", 100.0 * p.adc_energy_fraction),
                (rank + 1).to_string(),
            ]);
        }
        println!(
            "{}: {} Pareto-optimal configs (of {}), best-EAP = {}",
            workload.name,
            front.len(),
            points.len(),
            best.arch.name
        );
        println!("{}", t.render());
    }

    // Structural expectation: tiny-tensor workloads choose smaller analog
    // sums than dense large-tensor workloads.
    let get = |name: &str| best_sum_sizes.iter().find(|(n, _)| n == name).unwrap().1;
    assert!(
        get("lenet") <= get("vgg16"),
        "lenet sum {} should be <= vgg16 sum {}",
        get("lenet"),
        get("vgg16")
    );
    println!(
        "ok: best sum size scales with workload tensor size: lenet {} <= resnet18 {} ~ vgg16 {}\n",
        get("lenet"),
        get("resnet18"),
        get("vgg16")
    );

    // CIMDSE_BENCH_QUICK shrinks the measurement budget.
    let bench = Bench::auto_slow();
    bench.run("accel DSE: 320 feasible candidates x lenet", || {
        std::hint::black_box(
            run_accel_sweep(&spec, &model, &lenet(), default_workers()).unwrap(),
        );
    });
}
