//! Bench: regenerate the paper's **Fig. 2** — published ADC throughput vs
//! energy, with the model's two-bound lines for 4b/8b/12b at 32 nm —
//! and time the full figure pipeline (survey synth → fit → series).
//!
//! Run with `cargo bench --bench fig2_energy`.

use cimdse::adc::{AdcModel, fit_model};
use cimdse::bench_util::{Bench, scale};
use cimdse::dse::figures;
use cimdse::report::Table;
use cimdse::survey::generator::{SurveyConfig, generate_survey};

fn main() {
    let survey = generate_survey(&SurveyConfig::default());
    let model = AdcModel::new(fit_model(&survey).unwrap().coefs);
    let line_points = scale(40, 12); // CIMDSE_BENCH_QUICK shrinks the lines

    // --- the figure itself -------------------------------------------------
    let data = figures::fig2(&survey, &model, line_points);
    println!(
        "{}",
        figures::render_fig23(
            &data,
            "Fig. 2: ADC throughput vs energy (32 nm; dots = survey, lines = model bounds)",
            "energy (pJ/convert)"
        )
    );

    // Machine-readable series (the paper's rows).
    let mut t = Table::new(vec!["enob", "throughput", "model energy (pJ/convert)"]);
    for (enob, pts) in &data.lines {
        for &(f, e) in pts.iter().step_by(4) {
            t.row(vec![format!("{enob}"), format!("{f:.3e}"), format!("{e:.4e}")]);
        }
    }
    println!("CSV:\n{}", t.to_csv());

    // Structural assertions the paper states (§II-A): flat at low f,
    // rising at high f, knee earlier for higher ENOB.
    for (enob, pts) in &data.lines {
        let flat = pts[1].1 / pts[0].1;
        assert!((flat - 1.0).abs() < 1e-6, "{enob}b not flat at low throughput");
        let rising = pts[pts.len() - 1].1 / pts[pts.len() - 2].1;
        assert!(rising > 1.0, "{enob}b not rising at high throughput");
    }
    let knee = |enob: f64| model.crossover_throughput(enob, 32.0);
    assert!(knee(12.0) < knee(8.0) && knee(8.0) < knee(4.0));
    println!(
        "knees: 4b {:.2e}, 8b {:.2e}, 12b {:.2e} converts/s (falling with ENOB ok)\n",
        knee(4.0),
        knee(8.0),
        knee(12.0)
    );

    // --- timing -------------------------------------------------------------
    let bench = Bench::auto();
    bench.run("fig2: survey synthesis (700 records)", || {
        std::hint::black_box(generate_survey(&SurveyConfig::default()));
    });
    bench.run("fig2: envelope fit", || {
        std::hint::black_box(fit_model(&survey).unwrap());
    });
    bench.run("fig2: figure series generation", || {
        std::hint::black_box(figures::fig2(&survey, &model, line_points));
    });
}
