//! Bench: serving-daemon request throughput, both cores.
//!
//! Spins up an in-process `service::Server` on an ephemeral port and
//! measures `eval` requests/s at 1/4/16/64 concurrent client
//! connections for **each serving core** — the readiness-driven event
//! loop and the original thread-per-connection core — on a cached model
//! (every request reuses the default model — pure protocol +
//! cache-hit path) vs uncached models (every request carries a fresh
//! tuning offset, forcing a fingerprint miss and a prepare).
//!
//! Writes the machine-readable report to `BENCH_serve.json`
//! (`bench_util::JsonReport` schema, validated by
//! `cimdse bench-report`); honors `CIMDSE_BENCH_QUICK` like every other
//! bench. Run with `cargo bench --bench bench_serve`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::thread;

use cimdse::adc::{AdcModel, AdcQuery};
use cimdse::bench_util::{Bench, JsonReport, quick, scale};
use cimdse::service::{Client, ServeCore, ServeOptions, Server};

/// Monotonic counter so every "uncached" request names a distinct model.
static UNCACHED_SEQ: AtomicU64 = AtomicU64::new(1);

fn query_for(i: usize) -> AdcQuery {
    AdcQuery {
        enob: 2.0 + (i % 12) as f64,
        total_throughput: 1e6 * 10f64.powi((i % 5) as i32),
        tech_nm: 32.0,
        n_adcs: 1 + (i % 8) as u32,
    }
}

/// A model no prior request has used (distinct fingerprint every call).
fn fresh_model() -> AdcModel {
    let seq = UNCACHED_SEQ.fetch_add(1, Ordering::Relaxed);
    AdcModel {
        energy_offset_decades: seq as f64 * 1e-9,
        ..AdcModel::default()
    }
}

/// One iteration: every pre-connected client issues `per_client` eval
/// frames from its own thread. Connections persist across iterations —
/// the daemon's whole point — so the measurement is request throughput,
/// not TCP/accept churn.
fn drive(clients: &mut [Client], per_client: usize, cached: bool) {
    thread::scope(|s| {
        for (c, client) in clients.iter_mut().enumerate() {
            s.spawn(move || {
                for i in 0..per_client {
                    let q = query_for(c * per_client + i);
                    let model = if cached { None } else { Some(fresh_model()) };
                    client
                        .eval_metrics(&q, model.as_ref())
                        .expect("bench eval");
                }
            });
        }
    });
}

fn core_tag(core: ServeCore) -> &'static str {
    match core {
        ServeCore::EventLoop => "event-loop",
        ServeCore::Threads => "threads",
    }
}

fn main() {
    let bench = Bench::auto();
    let mut report = JsonReport::new("serve");
    if quick() {
        println!("(CIMDSE_BENCH_QUICK: reduced budgets and request counts)\n");
    }

    let per_client = scale(64, 16);
    for &core in &[ServeCore::EventLoop, ServeCore::Threads] {
        let server = Server::bind(ServeOptions {
            addr: "127.0.0.1:0".to_string(),
            model: AdcModel::default(),
            // Smaller than the uncached stream so misses also exercise
            // eviction, the cache's steady state under model churn.
            cache_capacity: 16,
            workers: cimdse::exec::default_workers(),
            core,
            ..ServeOptions::default()
        })
        .expect("bind bench server");
        let addr = server.local_addr().to_string();
        let handle = server.handle();
        let serve_thread = thread::spawn(move || server.serve().expect("serve"));

        println!("== {} core ==", core_tag(core));
        let mut baseline_rps = None;
        for &clients in &[1usize, 4, 16, 64] {
            let mut pool: Vec<Client> = (0..clients)
                .map(|_| Client::connect(&addr).expect("bench client connect"))
                .collect();
            let requests = clients * per_client;
            for cached in [true, false] {
                let label = format!(
                    "[{}] eval x{requests}: {clients} client(s), {} model",
                    core_tag(core),
                    if cached { "cached" } else { "uncached" }
                );
                let stats = bench.run(&label, || drive(&mut pool, per_client, cached));
                // `points` = requests per iteration, so mpts_per_s in the
                // report is literally Mrequests/s.
                report.case(&label, &stats, requests);
                let rps = requests as f64 / stats.median_s;
                println!("  -> {rps:.0} requests/s");
                if cached {
                    if clients == 1 {
                        baseline_rps = Some(rps);
                    } else if let Some(base) = baseline_rps {
                        report.metric(
                            &format!(
                                "scaling_cached_{}_{clients}_clients",
                                core_tag(core).replace('-', "_")
                            ),
                            rps / base,
                        );
                    }
                }
            }
        }

        // Histogram-derived latency quantiles for the whole core's run,
        // straight from the server's own `metrics` op — the same numbers
        // an operator would see, covering every frame (errors included).
        let snapshot = Client::connect(&addr)
            .and_then(|mut c| c.metrics())
            .expect("bench metrics");
        let tag = core_tag(core).replace('-', "_");
        for (key, metric) in [("p50_s", "latency_p50_s"), ("p99_s", "latency_p99_s")] {
            let v = snapshot
                .get("latency")
                .and_then(|l| l.get(key))
                .and_then(cimdse::config::Value::as_f64)
                .expect("latency quantile in metrics snapshot");
            report.metric(&format!("{metric}_{tag}"), v);
            println!("  {} {metric} = {v:.6}s", core_tag(core));
        }

        handle.shutdown();
        serve_thread.join().expect("serve thread");
    }

    let path = report.write().expect("writing bench report");
    println!("\nwrote serve throughput report to {path}");
}
