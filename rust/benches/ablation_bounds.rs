//! Ablation bench: the design choices DESIGN.md calls out.
//!
//! 1. **Two-bound vs single-bound energy model** — Murmann's two-bound
//!    observation (§II-A) vs a single log-linear regression in
//!    (ENOB, tech, log f). The two-bound form should explain the survey
//!    envelope better (lower RMSE against the lower envelope, no
//!    systematic flat-region bias).
//! 2. **Envelope quantile** — sensitivity of the fit to the best-case
//!    quantile (q = 0.01 / 0.05 / 0.15 / 0.50): intercepts shift, slopes
//!    stay put (the paper's trends are quantile-robust).
//! 3. **Area predictor** — ENOB vs energy (the paper's r comparison),
//!    over multiple survey seeds, to show the improvement is systematic.
//!
//! Run with `cargo bench --bench ablation_bounds`.

use cimdse::adc::fit_model;
use cimdse::bench_util::{Bench, scale};
use cimdse::report::Table;
use cimdse::stats::ols::ols;
use cimdse::stats::piecewise::{EnergyPoint, fit_two_bound_envelope};
use cimdse::survey::generator::{SurveyConfig, generate_survey};
use cimdse::util::logspace::log10;

fn main() {
    let survey = generate_survey(&SurveyConfig::default());
    let points: Vec<EnergyPoint> = survey
        .records
        .iter()
        .map(|r| EnergyPoint {
            enob: r.enob,
            log_t: r.log_tech_ratio(),
            log_f: log10(r.throughput),
            log_e: log10(r.energy_pj),
        })
        .collect();

    // --- ablation 1: two-bound vs single-bound -----------------------------
    let two = fit_two_bound_envelope(&points, 0.05).unwrap();
    let xs: Vec<Vec<f64>> = points.iter().map(|p| vec![p.enob, p.log_t, p.log_f]).collect();
    let ys: Vec<f64> = points.iter().map(|p| p.log_e).collect();
    let single = ols(&xs, &ys).unwrap();

    // Compare on central-fit residual structure: within the flat region
    // (below each point's crossover) the single model is forced to tilt
    // with log f; measure the |slope| it assigns there via residual trend.
    let rmse = |pred: &dyn Fn(&EnergyPoint) -> f64| -> f64 {
        (points
            .iter()
            .map(|p| {
                let d = p.log_e - pred(p);
                d * d
            })
            .sum::<f64>()
            / points.len() as f64)
            .sqrt()
    };
    // Shift the two-bound envelope up to a central fit for an apples-to-
    // apples RMSE (envelope_q = 0.5).
    let two_central = fit_two_bound_envelope(&points, 0.5).unwrap();
    let rmse_two = rmse(&|p| two_central.log_energy(p.enob, p.log_t, p.log_f));
    let rmse_single = rmse(&|p| single.predict(&[p.enob, p.log_t, p.log_f]));

    let mut t = Table::new(vec!["energy model form", "RMSE (decades)", "notes"]);
    t.row(vec![
        "single log-linear (ablation)".to_string(),
        format!("{rmse_single:.4}"),
        "forced throughput slope everywhere".to_string(),
    ]);
    t.row(vec![
        "two-bound max (paper §II-A)".to_string(),
        format!("{rmse_two:.4}"),
        format!("{:.0}% of points on tradeoff bound", 100.0 * two.trade_fraction),
    ]);
    println!("ablation 1 — energy model form:\n{}", t.render());
    assert!(
        rmse_two < rmse_single,
        "two-bound ({rmse_two}) should beat single-bound ({rmse_single})"
    );
    println!("ok: two-bound model fits better by {:.1}%\n",
        100.0 * (rmse_single - rmse_two) / rmse_single);

    // --- ablation 2: envelope quantile --------------------------------------
    let mut t = Table::new(vec!["envelope q", "a0 (intercept)", "a1 (ENOB slope)", "b3 (thpt slope)"]);
    let mut slopes = Vec::new();
    for q in [0.01, 0.05, 0.15, 0.50] {
        let fit = fit_two_bound_envelope(&points, q).unwrap();
        slopes.push((fit.flat[1], fit.trade[3]));
        t.row(vec![
            format!("{q:.2}"),
            format!("{:+.3}", fit.flat[0]),
            format!("{:+.3}", fit.flat[1]),
            format!("{:+.3}", fit.trade[3]),
        ]);
    }
    println!("ablation 2 — envelope quantile sensitivity:\n{}", t.render());
    // Slopes are quantile-invariant (only intercepts shift).
    for w in slopes.windows(2) {
        assert!((w[0].0 - w[1].0).abs() < 1e-9, "ENOB slope moved with quantile");
        assert!((w[0].1 - w[1].1).abs() < 1e-9, "throughput slope moved with quantile");
    }
    println!("ok: slopes are exactly quantile-invariant; only intercepts calibrate\n");

    // --- ablation 3: area predictor across seeds ----------------------------
    let mut t = Table::new(vec!["seed", "r (ENOB)", "r (energy)", "improvement"]);
    let mut wins = 0;
    const ALL_SEEDS: [u64; 5] = [1997, 2003, 2011, 2017, 2023];
    // CIMDSE_BENCH_QUICK: re-fit on 3 seeds instead of 5.
    let seeds = &ALL_SEEDS[..scale(ALL_SEEDS.len(), 3)];
    for &seed in seeds {
        let sv = generate_survey(&SurveyConfig { seed, ..SurveyConfig::default() });
        let report = fit_model(&sv).unwrap();
        if report.area_r_energy > report.area_r_enob {
            wins += 1;
        }
        t.row(vec![
            seed.to_string(),
            format!("{:.3}", report.area_r_enob),
            format!("{:.3}", report.area_r_energy),
            format!("{:+.3}", report.area_r_energy - report.area_r_enob),
        ]);
    }
    println!("ablation 3 — area predictor (paper §II-B, r 0.66 -> 0.75):\n{}", t.render());
    assert_eq!(wins, seeds.len(), "energy predictor must win on every seed");
    println!("ok: energy predictor beats ENOB on {wins}/{} seeds\n", seeds.len());

    // --- timing --------------------------------------------------------------
    let bench = Bench::auto();
    bench.run("two-bound envelope fit (700 pts)", || {
        std::hint::black_box(fit_two_bound_envelope(&points, 0.05).unwrap());
    });
    bench.run("single-bound OLS fit (700 pts)", || {
        std::hint::black_box(ols(&xs, &ys).unwrap());
    });
}
