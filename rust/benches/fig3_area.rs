//! Bench: regenerate the paper's **Fig. 3** — published ADC throughput vs
//! area with model lines (Eq. 1 + p10 calibration) — and time it.
//!
//! Run with `cargo bench --bench fig3_area`.

use cimdse::adc::{AdcModel, AdcQuery, fit_model};
use cimdse::bench_util::{Bench, scale};
use cimdse::dse::figures;
use cimdse::report::Table;
use cimdse::survey::generator::{SurveyConfig, generate_survey};

fn main() {
    let survey = generate_survey(&SurveyConfig::default());
    let model = AdcModel::new(fit_model(&survey).unwrap().coefs);
    let line_points = scale(40, 12); // CIMDSE_BENCH_QUICK shrinks the lines

    let data = figures::fig3(&survey, &model, line_points);
    println!(
        "{}",
        figures::render_fig23(
            &data,
            "Fig. 3: ADC throughput vs area (32 nm; dots = survey, lines = model)",
            "area (µm²)"
        )
    );

    let mut t = Table::new(vec!["enob", "throughput", "model area (µm²)"]);
    for (enob, pts) in &data.lines {
        for &(f, a) in pts.iter().step_by(4) {
            t.row(vec![format!("{enob}"), format!("{f:.3e}"), format!("{a:.4e}")]);
        }
    }
    println!("CSV:\n{}", t.to_csv());

    // Paper §II-B structure: "as throughput increases, area first increases
    // slowly, then quickly — because the two energy bounds influence area".
    for (enob, pts) in &data.lines {
        let early = pts[4].1 / pts[0].1; // growth below the knee
        let late = pts[pts.len() - 1].1 / pts[pts.len() - 5].1; // above the knee
        assert!(
            late > early,
            "{enob}b: area growth should steepen past the knee ({early:.3} vs {late:.3})"
        );
    }
    // Area rises with ENOB at fixed throughput.
    let area = |enob: f64| {
        model.area_um2_per_adc(&AdcQuery {
            enob,
            total_throughput: 1e8,
            tech_nm: 32.0,
            n_adcs: 1,
        })
    };
    assert!(area(4.0) < area(8.0) && area(8.0) < area(12.0));
    println!(
        "area @1e8 conv/s: 4b {:.0} µm², 8b {:.0} µm², 12b {:.0} µm² (rising with ENOB ok)\n",
        area(4.0),
        area(8.0),
        area(12.0)
    );

    let bench = Bench::auto();
    bench.run("fig3: figure series generation", || {
        std::hint::black_box(figures::fig3(&survey, &model, line_points));
    });
    bench.run("fig3: single area query", || {
        std::hint::black_box(model.area_um2_per_adc(&AdcQuery {
            enob: 8.0,
            total_throughput: 1e9,
            tech_nm: 32.0,
            n_adcs: 1,
        }));
    });
}
