//! Bench: regenerate the paper's **Fig. 5** — accelerator energy-area
//! product vs number of ADCs across total-throughput requirements —
//! assert the paper's three findings, and time the EAP sweep.
//!
//! Run with `cargo bench --bench fig5_eap`.

use cimdse::adc::{AdcModel, fit_model};
use cimdse::bench_util::Bench;
use cimdse::dse::figures;
use cimdse::survey::generator::{SurveyConfig, generate_survey};

fn main() {
    let survey = generate_survey(&SurveyConfig::default());
    let model = AdcModel::new(fit_model(&survey).unwrap().coefs);

    let cells = figures::fig5(&model, 5).unwrap();
    println!("Fig. 5: accelerator EAP vs number of ADCs for varying throughputs");
    println!("{}", figures::render_fig5(&cells).render());
    println!("CSV:\n{}", figures::render_fig5(&cells).to_csv());

    let mut tps: Vec<f64> = cells.iter().map(|c| c.total_throughput).collect();
    tps.dedup();
    let group = |tp: f64| -> Vec<&figures::Fig5Cell> {
        cells.iter().filter(|c| c.total_throughput == tp).collect()
    };

    // (1) higher total throughput -> higher (minimum) EAP.
    let min_eap = |tp: f64| group(tp).iter().map(|c| c.eap).fold(f64::MAX, f64::min);
    for w in tps.windows(2) {
        assert!(min_eap(w[1]) > min_eap(w[0]), "EAP did not grow with throughput");
    }
    println!("finding 1 ok: min EAP grows with total throughput");

    // (2) the n_adcs choice can swing EAP by ~3x.
    let max_swing = tps
        .iter()
        .map(|&tp| {
            let g = group(tp);
            let hi = g.iter().map(|c| c.eap).fold(f64::MIN, f64::max);
            let lo = g.iter().map(|c| c.eap).fold(f64::MAX, f64::min);
            hi / lo
        })
        .fold(f64::MIN, f64::max);
    assert!(max_swing >= 3.0, "max EAP swing only {max_swing:.2}x");
    println!("finding 2 ok: n_adcs choice swings EAP up to {max_swing:.1}x (paper: ~3x)");

    // (3) optimal n_adcs grows with throughput: few ADCs at low demand
    // (area), many at high demand (energy).
    let opt = |tp: f64| {
        group(tp)
            .iter()
            .min_by(|a, b| a.eap.total_cmp(&b.eap))
            .unwrap()
            .n_adcs
    };
    let opts: Vec<u32> = tps.iter().map(|&tp| opt(tp)).collect();
    assert!(opts.windows(2).all(|w| w[1] >= w[0]), "optima not monotone: {opts:?}");
    assert!(opts[0] < *opts.last().unwrap(), "optimum never moved: {opts:?}");
    println!("finding 3 ok: optimal n_adcs per throughput = {opts:?}\n");

    // --- timing (CIMDSE_BENCH_QUICK shrinks the budgets) --------------------
    let bench = Bench::auto();
    bench.run("fig5: one throughput column (5 EAP cells)", || {
        std::hint::black_box(figures::fig5(&model, 2).unwrap());
    });
    bench.run("fig5: full 25-cell grid", || {
        std::hint::black_box(figures::fig5(&model, 5).unwrap());
    });
}
