//! Offline API shim for the `xla` crate (the xla-rs / PJRT bindings).
//!
//! The offline registry does not carry the real `xla` crate, whose build
//! also requires `libxla_extension` at link time. This shim mirrors the
//! API surface that `cimdse::runtime::pjrt` consumes so that
//! `cargo build --features pjrt` type-checks from a cold checkout; every
//! entry point returns [`Error`] at runtime. To run the real PJRT path,
//! replace this path dependency with the actual bindings (same names,
//! same signatures) — no cimdse code changes are required.

use std::fmt;

/// Error type mirroring `xla::Error` (shim: carries a message only).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Shim result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "xla shim: the real XLA/PJRT runtime is not linked in this build \
         (replace rust/vendor/xla with the actual xla bindings)"
            .to_string(),
    )
}

/// Element types of XLA literals (only F32 is used by cimdse).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit IEEE float.
    F32,
}

/// A host-side literal (shim: opaque, never constructible at runtime).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    /// Build a literal from a shape and raw bytes (one memcpy in the real
    /// bindings).
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable())
    }

    /// Unwrap a 1-tuple literal.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }

    /// Copy the literal out as a typed vector.
    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// A PJRT client (shim: construction always fails).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    /// The PJRT platform name.
    pub fn platform_name(&self) -> String {
        "xla-shim".to_string()
    }

    /// Compile an XLA computation for this client.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// A parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse HLO text from a file (instruction ids are reassigned by the
    /// real parser, which is why text is the interchange format).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a module proto as a computation.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A device buffer returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with the given inputs; returns per-device, per-output
    /// buffers (cimdse uses device 0, output 0).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_errors_helpfully() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let err = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4])
            .unwrap_err()
            .to_string();
        assert!(err.contains("xla shim"), "{err}");
    }
}
