"""Canonical default coefficients of the ADC energy/area model.

The model (paper §II) in log10 space. Let

    B  = ENOB (effective number of bits)
    f  = per-ADC throughput (converts / second)
    t  = log10(tech_nm / 32)          (tech node, normalized to 32 nm)

Energy per convert (picojoules) is the max of two bounds (Murmann's
two-bound observation, extended with ENOB + tech dependence):

    log10 E_min   = a0 + a1*B + a2*t                      (minimum-energy bound)
    log10 E_trade = b0 + b1*B + b2*t + b3*log10(f)        (energy-throughput tradeoff)
    E_pJ = 10 ** max(log10 E_min, log10 E_trade)

Because b1 > a1, the crossover throughput where the tradeoff bound takes
over falls by (b1 - a1)/b3 decades per ENOB bit — the paper's "the
energy-throughput-tradeoff bound begins to affect high-ENOB ADCs at
relatively lower throughputs".

Area (um^2) follows the paper's Eq. 1 with an optimistic calibration
factor kappa fit to the lowest-area 10% of the survey:

    Area = kappa * 21.1 * Tech(nm)^1.0 * f^0.2 * E_pJ^0.3
    log10 Area = d0 + d1*t + d2*log10(f) + d3*log10(E_pJ)
    with d0 = log10(kappa * 21.1 * 32^d1)

These defaults are the *generator truth* used to synthesize the survey
(DESIGN.md §2); the Rust fit pipeline re-derives them from the synthetic
survey and the artifact accepts fitted coefficients as a runtime input,
so nothing downstream is hard-wired to these numbers.
"""

import numpy as np

# --- energy: minimum-energy bound ------------------------------------------
A0 = -2.301  # 4b ADC @ 32nm: 0.05 pJ/convert
A1 = 0.250   # +1 ENOB bit => x1.78 energy (x10 per 4 bits)
A2 = 1.000   # energy ~ tech node (digital/CDAC-limited regime)

# --- energy: energy-throughput-tradeoff bound ------------------------------
B0 = -14.840  # anchors the 8b corner at ~2.8e8 conv/s @ 32nm (4b: ~2.8e9)
B1 = 0.550    # crossover falls 0.25 decades per ENOB bit: (B1-A1)/B3 = 0.25
B2 = 1.000
B3 = 1.200    # superlinear energy growth with throughput past the corner

# --- area: Eq. 1 + lowest-10% calibration ----------------------------------
# p10 calibration factor (paper: "optimistically reduce ... to match the
# lowest-area 10%"). Consistent with the survey generator's 0.55-decade
# log-normal area scatter: 10^(-1.2816 * 0.55) ~= 0.20.
KAPPA = 0.20
D1 = 1.0              # Tech(nm)^1.0
D2 = 0.2              # Throughput^0.2
D3 = 0.3              # (Energy pJ / convert)^0.3
D0 = float(np.log10(KAPPA * 21.1) + D1 * np.log10(32.0))

#: Coefficient vector layout consumed by the kernel / the HLO artifact.
COEF_NAMES = ["a0", "a1", "a2", "b0", "b1", "b2", "b3", "d0", "d1", "d2", "d3"]
DEFAULT_COEFS = np.array(
    [A0, A1, A2, B0, B1, B2, B3, D0, D1, D2, D3], dtype=np.float32
)

N_COEFS = len(COEF_NAMES)
N_PARAMS = 4   # [enob, log10_f_per_adc, log10_tech_ratio, n_adcs]
N_METRICS = 4  # [E_pJ_per_convert, area_um2_per_adc, total_power_W, total_area_um2]
