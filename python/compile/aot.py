"""AOT export: lower the Layer-2 graphs to HLO *text* artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run as ``python -m compile.aot --out-dir ../artifacts`` (what ``make
artifacts`` does). Python never runs after this: the Rust binary loads the
text artifacts via PJRT and is self-contained.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .coeffs import DEFAULT_COEFS, N_COEFS, N_METRICS, N_PARAMS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_adc_model() -> str:
    return to_hlo_text(
        jax.jit(model.adc_model_batch).lower(
            f32(model.DSE_BATCH, N_PARAMS), f32(N_COEFS)
        )
    )


def lower_crossbar() -> str:
    return to_hlo_text(
        jax.jit(model.crossbar_layer).lower(
            f32(model.MLP_BATCH, model.MLP_IN),
            f32(model.MLP_IN, model.MLP_HIDDEN),
            f32(1),
        )
    )


def lower_cim_mlp() -> str:
    return to_hlo_text(
        jax.jit(model.cim_mlp).lower(
            f32(model.MLP_BATCH, model.MLP_IN),
            f32(model.MLP_IN, model.MLP_HIDDEN),
            f32(model.MLP_HIDDEN, model.MLP_OUT),
            f32(1),
            f32(1),
            f32(1),
        )
    )


ARTIFACTS = {
    "adc_model.hlo.txt": lower_adc_model,
    "crossbar.hlo.txt": lower_crossbar,
    "cim_mlp.hlo.txt": lower_cim_mlp,
}


def manifest() -> dict:
    """Shape/layout contract consumed by the Rust runtime at load time."""
    return {
        "adc_model": {
            "file": "adc_model.hlo.txt",
            "batch": model.DSE_BATCH,
            "n_params": N_PARAMS,
            "n_metrics": N_METRICS,
            "n_coefs": N_COEFS,
            "default_coefs": [float(c) for c in DEFAULT_COEFS],
        },
        "crossbar": {
            "file": "crossbar.hlo.txt",
            "batch": model.MLP_BATCH,
            "in_dim": model.MLP_IN,
            "out_dim": model.MLP_HIDDEN,
            "n_sum": model.MLP_NSUM_1,
            "x_bits": model.X_BITS,
            "cell_bits": model.CELL_BITS,
        },
        "cim_mlp": {
            "file": "cim_mlp.hlo.txt",
            "batch": model.MLP_BATCH,
            "in_dim": model.MLP_IN,
            "hidden_dim": model.MLP_HIDDEN,
            "out_dim": model.MLP_OUT,
            "n_sum_1": model.MLP_NSUM_1,
            "n_sum_2": model.MLP_NSUM_2,
            "x_bits": model.X_BITS,
            "cell_bits": model.CELL_BITS,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(compat) single-file target; "
                    "writes all artifacts into its directory")
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    for name, lower in ARTIFACTS.items():
        path = os.path.join(out_dir, name)
        text = lower()
        with open(path, "w") as fh:
            fh.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as fh:
        json.dump(manifest(), fh, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
