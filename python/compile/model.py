"""Layer 2: JAX compute graphs built on the Layer-1 Pallas kernels.

Two graphs are AOT-lowered to HLO text (aot.py) and executed from Rust:

  * ``adc_model_batch`` — the DSE evaluation graph. The Rust sweep engine
    streams (BATCH, 4) design-point tiles plus the fitted 11-coefficient
    vector through the compiled executable.
  * ``cim_mlp`` — a two-layer MLP whose matmuls run entirely through the
    bit-sliced CiM crossbar kernel, including inter-layer requantization.
    Used by the functional-sim example to demonstrate that the datapath
    the energy model prices actually computes.

Everything here is build-time Python; nothing in this package is imported
at runtime.
"""

import jax.numpy as jnp

from .kernels.adc_model import adc_model
from .kernels.crossbar import cim_matmul

#: Compile-time batch of the DSE evaluation artifact. The Rust side pads
#: the final partial tile. Must be a multiple of kernels.adc_model.BLOCK.
DSE_BATCH = 4096

#: Compile-time shapes of the functional-sim MLP (16x16 digit images).
MLP_BATCH = 32
MLP_IN = 256
MLP_HIDDEN = 64
MLP_OUT = 16  # 10 classes, padded to 16 for lane alignment
MLP_NSUM_1 = 128  # analog sum size, layer 1 (RAELLA-S-like)
MLP_NSUM_2 = 64   # analog sum size, layer 2 (column-limited)
X_BITS = 4
CELL_BITS = 2


def adc_model_batch(params, coefs):
    """DSE evaluation graph: (DSE_BATCH, 4) design points -> (DSE_BATCH, 4).

    Returns a 1-tuple so the lowered HLO root is a tuple (the Rust loader
    unwraps with ``to_tuple1``).
    """
    return (adc_model(params, coefs),)


def cim_linear(x_q, w_q, adc_step, n_sum):
    """One CiM crossbar layer (thin alias with the artifact's static config)."""
    return cim_matmul(
        x_q, w_q, adc_step, n_sum=n_sum, x_bits=X_BITS, cell_bits=CELL_BITS
    )


def crossbar_layer(x_q, w_q, adc_step):
    """Single-layer functional-check graph: (B, IN) @ (IN, HIDDEN)."""
    return (cim_linear(x_q, w_q, adc_step, MLP_NSUM_1),)


def requantize(y, scale, x_bits=X_BITS):
    """Digital requantization between CiM layers: scale, ReLU, clip to DAC range."""
    q = jnp.round(y * scale)
    return jnp.clip(q, 0.0, float(2**x_bits - 1))


def cim_mlp(x_q, w1_q, w2_q, step1, step2, scale1):
    """Two-layer CiM MLP forward, every matmul through the crossbar kernel.

    Args:
      x_q: f32[MLP_BATCH, MLP_IN] integer activations in [0, 2^X_BITS).
      w1_q: f32[MLP_IN, MLP_HIDDEN] integer weights in [0, 2^(2*CELL_BITS)).
      w2_q: f32[MLP_HIDDEN, MLP_OUT] integer weights.
      step1, step2: f32[1] runtime ADC quantization steps per layer.
      scale1: f32[1] inter-layer requantization scale.

    Returns:
      (f32[MLP_BATCH, MLP_OUT],) logits (padded classes stay near zero when
      the corresponding weight columns are zero).
    """
    h = cim_linear(x_q, w1_q, step1, MLP_NSUM_1)
    h_q = requantize(h, scale1[0])
    logits = cim_linear(h_q, w2_q, step2, MLP_NSUM_2)
    return (logits,)
