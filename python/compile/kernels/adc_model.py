"""Pallas kernel: batched ADC energy/area model evaluation.

This is the DSE hot-spot (Layer 1). The Rust coordinator sweeps millions of
design points; each point is four architecture-level attributes and the
model is a pair of piecewise power laws plus Eq. 1 — pure element-wise math,
so the kernel is a VPU (vector-unit) kernel tiled over the design-point
batch.

TPU mapping (DESIGN.md §8): design points are tiled in (BLOCK, 4)-shaped
VMEM blocks with an 8x128-aligned BLOCK; the 11-entry coefficient vector is
replicated into every grid step (index_map -> 0). There is no MXU work —
the roofline is VPU/memory-bound, so the only structural knobs are block
size (VMEM residency) and fusing the energy/area/power outputs into a
single pass, which this kernel does.

Pallas runs with interpret=True: on this CPU PJRT build the kernel lowers
to plain HLO so the Rust runtime can execute it; numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Design-point rows per grid step. 512 rows x 4 cols f32 in + 512 x 4 out
# = 16 KiB VMEM per step — far under the ~16 MiB VMEM budget; chosen so the
# grid still has enough steps to pipeline HBM->VMEM copies on real hardware.
BLOCK = 512

N_PARAMS = 4
N_METRICS = 4
N_COEFS = 11


def _adc_model_kernel(params_ref, coefs_ref, out_ref):
    """One grid step: evaluate the model on a (BLOCK, 4) tile of points."""
    p = params_ref[...]  # (BLOCK, 4)
    c = coefs_ref[...]   # (11,)

    enob = p[:, 0]
    log_f = p[:, 1]
    log_t = p[:, 2]
    n_adcs = p[:, 3]

    # Energy: max of the two bounds (paper §II-A), all in log10 space.
    log_e_min = c[0] + c[1] * enob + c[2] * log_t
    log_e_trade = c[3] + c[4] * enob + c[5] * log_t + c[6] * log_f
    log_e = jnp.maximum(log_e_min, log_e_trade)
    energy_pj = 10.0 ** log_e

    # Area: Eq. 1 in log10 space (the p10 calibration lives in d0).
    log_area = c[7] + c[8] * log_t + c[9] * log_f + c[10] * log_e
    area_um2 = 10.0 ** log_area

    total_power_w = energy_pj * 1e-12 * (10.0 ** log_f) * n_adcs
    total_area_um2 = area_um2 * n_adcs

    out_ref[...] = jnp.stack(
        [energy_pj, area_um2, total_power_w, total_area_um2], axis=1
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def adc_model(params, coefs, interpret=True):
    """Evaluate the ADC model for a batch of design points.

    Args:
      params: f32[N, 4] — [enob, log10_f_per_adc, log10_tech_ratio, n_adcs]
        per row; N must be a multiple of BLOCK (the Rust side pads).
      coefs: f32[11] — fitted model coefficients (see coeffs.py for layout).
      interpret: run Pallas in interpret mode (required for CPU PJRT).

    Returns:
      f32[N, 4] — [energy_pJ_per_convert, area_um2_per_adc, total_power_W,
      total_area_um2] per row.
    """
    n = params.shape[0]
    if n % BLOCK != 0:
        raise ValueError(f"batch size {n} must be a multiple of {BLOCK}")
    grid = (n // BLOCK,)
    return pl.pallas_call(
        _adc_model_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK, N_PARAMS), lambda i: (i, 0)),
            pl.BlockSpec((N_COEFS,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK, N_METRICS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, N_METRICS), jnp.float32),
        interpret=interpret,
    )(params, coefs)
