"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest asserts the Pallas kernels
(interpret=True) match these references bit-for-bit (same dtype, same op
order where it matters) or to tight float tolerance.
"""

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# ADC energy/area model (paper §II) — reference implementation
# ---------------------------------------------------------------------------

def adc_model_ref(params, coefs):
    """Evaluate the ADC model for a batch of design points.

    Args:
      params: f32[N, 4] — columns [enob, log10_f_per_adc, log10_tech_ratio,
        n_adcs]. ``log10_tech_ratio`` is log10(tech_nm / 32).
      coefs: f32[11] — [a0,a1,a2, b0,b1,b2,b3, d0,d1,d2,d3], see coeffs.py.

    Returns:
      f32[N, 4] — [energy_pJ_per_convert, area_um2_per_adc,
                   total_power_W, total_area_um2].
    """
    enob = params[:, 0]
    log_f = params[:, 1]
    log_t = params[:, 2]
    n_adcs = params[:, 3]

    a0, a1, a2 = coefs[0], coefs[1], coefs[2]
    b0, b1, b2, b3 = coefs[3], coefs[4], coefs[5], coefs[6]
    d0, d1, d2, d3 = coefs[7], coefs[8], coefs[9], coefs[10]

    log_e_min = a0 + a1 * enob + a2 * log_t
    log_e_trade = b0 + b1 * enob + b2 * log_t + b3 * log_f
    log_e = jnp.maximum(log_e_min, log_e_trade)
    energy_pj = 10.0 ** log_e

    log_area = d0 + d1 * log_t + d2 * log_f + d3 * log_e
    area_um2 = 10.0 ** log_area

    # total power: E/convert * converts/s * number of ADCs
    total_power_w = energy_pj * 1e-12 * (10.0 ** log_f) * n_adcs
    total_area_um2 = area_um2 * n_adcs

    return jnp.stack([energy_pj, area_um2, total_power_w, total_area_um2], axis=1)


# ---------------------------------------------------------------------------
# CiM crossbar (bit-sliced analog MAC with ADC read-out) — reference
# ---------------------------------------------------------------------------

def adc_quantize_ref(v, full_scale, step):
    """ADC transfer function: clip to [0, full_scale], quantize to ``step``."""
    clipped = jnp.clip(v, 0.0, full_scale)
    return jnp.round(clipped / step) * step


def cim_matmul_ref(x_q, w_q, n_sum, x_bits, cell_bits, adc_step):
    """Bit-sliced CiM crossbar matmul with per-chunk ADC quantization.

    Models an analog crossbar: the input activations stream in one bit-plane
    at a time (1-bit DACs), weights are stored across ``cell_bits``-bit
    cells, at most ``n_sum`` rows are summed on an analog column line per
    ADC convert, and each column sum is read through the ADC transfer
    function before digital shift-add recombination.

    Args:
      x_q: f32[B, IN] integer-valued activations in [0, 2^x_bits).
      w_q: f32[IN, OUT] integer-valued weights in [0, 2^(2*cell_bits)).
        (two cell slices per weight: low/high ``cell_bits`` bits)
      n_sum: analog sum size (rows summed per ADC convert); divides IN.
      x_bits: DAC input resolution (bit-serial planes).
      cell_bits: bits stored per memory cell.
      adc_step: ADC quantization step on the analog column value.

    Returns:
      f32[B, OUT] — the digitally recombined (lossy) matmul result.
    """
    b, in_dim = x_q.shape
    out_dim = w_q.shape[1]
    n_chunks = in_dim // n_sum
    full_scale = float(n_sum * (2**cell_bits - 1))

    w_levels = float(2**cell_bits)
    w_lo = jnp.mod(w_q, w_levels)
    w_hi = jnp.floor_divide(w_q, w_levels)

    y = jnp.zeros((b, out_dim), dtype=jnp.float32)
    for s in range(x_bits):
        x_bit = jnp.mod(jnp.floor_divide(x_q, float(2**s)), 2.0)
        for ci, w_slice in enumerate((w_lo, w_hi)):
            acc = jnp.zeros((b, out_dim), dtype=jnp.float32)
            for c in range(n_chunks):
                rows = slice(c * n_sum, (c + 1) * n_sum)
                analog = x_bit[:, rows] @ w_slice[rows, :]
                acc = acc + adc_quantize_ref(analog, full_scale, adc_step)
            y = y + (2.0 ** (s + cell_bits * ci)) * acc
    return y


def exact_matmul_ref(x_q, w_q):
    """Lossless integer matmul — the ADC-free ground truth for error stats."""
    return x_q @ w_q


def sqnr_db(exact, lossy):
    """Signal-to-quantization-noise ratio in dB between two tensors."""
    sig = jnp.mean(exact**2)
    err = jnp.mean((exact - lossy) ** 2)
    return 10.0 * jnp.log10(sig / jnp.maximum(err, 1e-30))
