"""Layer-1 Pallas kernels and their pure-jnp reference oracles."""

from . import adc_model, crossbar, noisy, ref  # noqa: F401
