"""Pallas kernel: bit-sliced CiM crossbar matmul with ADC read-out.

Functional simulation of the analog datapath the energy model prices
(Layer 1). One RAELLA-style CiM array computes, per ADC convert, the
analog sum of up to ``n_sum`` rows on each column line; the sum is read
through the ADC transfer function (clip + uniform quantization) and then
digitally shift-added across input bit-planes and weight cell-slices.

GPU->TPU adaptation (DESIGN.md §8): the paper's analog column sum is the
MXU contraction dimension. Each (input-bit-plane, cell-slice) pair is one
(B, n_sum) @ (n_sum, OUT) matmul on the MXU; the ADC transfer function is
a VPU epilogue on the (B, OUT) tile; the HBM->VMEM BlockSpec schedule
streams row chunks exactly as the DACs stream rows into the array. The
grid iterates over row chunks so each chunk's slice of x and w is resident
in VMEM while the (B, OUT) accumulator stays in the output block across
grid steps (revisited output block => accumulate in place).

The ADC quantization step arrives as a runtime scalar input so the Rust
side can sweep ADC resolution against one compiled artifact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _crossbar_kernel(
    x_ref, w_ref, step_ref, out_ref, *, x_bits, cell_bits, full_scale, n_chunks
):
    """Grid step = one row chunk: all bit-planes x cell-slices of the chunk.

    x_ref:   (B, n_sum)    — this chunk's integer activations
    w_ref:   (n_sum, OUT)  — this chunk's integer weights (both slices packed)
    step_ref:(1,)          — ADC quantization step (runtime scalar)
    out_ref: (B, OUT)      — accumulator, revisited across grid steps
    """
    chunk = pl.program_id(0)

    @pl.when(chunk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]
    w = w_ref[...]
    step = step_ref[0]

    w_levels = float(2**cell_bits)
    w_lo = jnp.mod(w, w_levels)
    w_hi = jnp.floor_divide(w, w_levels)

    acc = jnp.zeros_like(out_ref)
    for s in range(x_bits):
        x_bit = jnp.mod(jnp.floor_divide(x, float(2**s)), 2.0)
        for ci, w_slice in enumerate((w_lo, w_hi)):
            # Analog column sum over <= n_sum rows (MXU matmul) ...
            analog = jnp.dot(x_bit, w_slice, preferred_element_type=jnp.float32)
            # ... read through the ADC transfer function (VPU epilogue).
            clipped = jnp.clip(analog, 0.0, full_scale)
            quant = jnp.round(clipped / step) * step
            acc = acc + (2.0 ** (s + cell_bits * ci)) * quant
    out_ref[...] = out_ref[...] + acc


@functools.partial(
    jax.jit, static_argnames=("n_sum", "x_bits", "cell_bits", "interpret")
)
def cim_matmul(x_q, w_q, adc_step, n_sum, x_bits=4, cell_bits=2, interpret=True):
    """Bit-sliced CiM crossbar matmul with per-chunk ADC quantization.

    Matches ``ref.cim_matmul_ref`` exactly (same op order in f32).

    Args:
      x_q: f32[B, IN] integer-valued activations in [0, 2^x_bits).
      w_q: f32[IN, OUT] integer-valued weights in [0, 2^(2*cell_bits)).
      adc_step: f32[1] runtime ADC quantization step.
      n_sum: analog sum size (rows per ADC convert); must divide IN.
      x_bits: DAC input resolution (bit-serial planes).
      cell_bits: bits per memory cell (weights span two cell slices).
      interpret: run Pallas in interpret mode (required for CPU PJRT).

    Returns:
      f32[B, OUT] — the digitally recombined (lossy) matmul.
    """
    b, in_dim = x_q.shape
    out_dim = w_q.shape[1]
    if in_dim % n_sum != 0:
        raise ValueError(f"IN={in_dim} must be a multiple of n_sum={n_sum}")
    n_chunks = in_dim // n_sum
    full_scale = float(n_sum * (2**cell_bits - 1))

    kernel = functools.partial(
        _crossbar_kernel,
        x_bits=x_bits,
        cell_bits=cell_bits,
        full_scale=full_scale,
        n_chunks=n_chunks,
    )
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((b, n_sum), lambda c: (0, c)),
            pl.BlockSpec((n_sum, out_dim), lambda c: (c, 0)),
            pl.BlockSpec((1,), lambda c: (0,)),
        ],
        out_specs=pl.BlockSpec((b, out_dim), lambda c: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, out_dim), jnp.float32),
        interpret=interpret,
    )(x_q, w_q, adc_step)
