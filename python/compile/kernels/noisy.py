"""Pallas kernel: crossbar matmul with a *noisy* ADC transfer function.

The paper measures resolution as ENOB — "effective ADC resolution after
considering nonidealities such as noise and nonlinearity". This variant
adds input-referred noise to each analog column sum before quantization,
so the functional simulation can measure effective ENOB *below* the
nominal bit count and validate the `adc::enob` composition rules
(quantization SNDR + noise SNDR combine as powers).

Noise is sampled in the Layer-2 graph (jax.random, counter-based threefry
with an explicit key input so the artifact stays deterministic given the
key) and streamed into the kernel per (chunk, bit-plane, cell-slice) —
inside the kernel it is just an add on the VPU epilogue.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _noisy_crossbar_kernel(
    x_ref, w_ref, noise_ref, step_ref, out_ref, *, x_bits, cell_bits, full_scale
):
    """Grid step = one row chunk (same schedule as kernels.crossbar).

    noise_ref: (1, x_bits*2, B, OUT) — this chunk's per-plane/slice noise.
    """
    chunk = pl.program_id(0)

    @pl.when(chunk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]
    w = w_ref[...]
    step = step_ref[0]

    w_levels = float(2**cell_bits)
    w_lo = jnp.mod(w, w_levels)
    w_hi = jnp.floor_divide(w, w_levels)

    acc = jnp.zeros_like(out_ref)
    for s in range(x_bits):
        x_bit = jnp.mod(jnp.floor_divide(x, float(2**s)), 2.0)
        for ci, w_slice in enumerate((w_lo, w_hi)):
            analog = jnp.dot(x_bit, w_slice, preferred_element_type=jnp.float32)
            # Input-referred ADC noise, then the ideal transfer function.
            noisy = analog + noise_ref[0, s * 2 + ci]
            clipped = jnp.clip(noisy, 0.0, full_scale)
            quant = jnp.round(clipped / step) * step
            acc = acc + (2.0 ** (s + cell_bits * ci)) * quant
    out_ref[...] = out_ref[...] + acc


@functools.partial(
    jax.jit, static_argnames=("n_sum", "x_bits", "cell_bits", "interpret")
)
def cim_matmul_noisy(
    x_q, w_q, adc_step, noise_sigma, key, n_sum, x_bits=4, cell_bits=2, interpret=True
):
    """Bit-sliced CiM matmul with Gaussian input-referred ADC noise.

    Args:
      x_q: f32[B, IN] integer activations.
      w_q: f32[IN, OUT] integer weights (two cell slices per weight).
      adc_step: f32[1] quantization step.
      noise_sigma: f32[1] noise std-dev in analog-sum units (0 => matches
        kernels.crossbar.cim_matmul exactly).
      key: jax PRNG key (threefry counter — deterministic per key).
      n_sum: analog sum size; must divide IN.

    Returns:
      f32[B, OUT].
    """
    b, in_dim = x_q.shape
    out_dim = w_q.shape[1]
    if in_dim % n_sum != 0:
        raise ValueError(f"IN={in_dim} must be a multiple of n_sum={n_sum}")
    n_chunks = in_dim // n_sum
    full_scale = float(n_sum * (2**cell_bits - 1))

    # One noise draw per (chunk, plane, slice, batch, column) analog read.
    noise = noise_sigma[0] * jax.random.normal(
        key, (n_chunks, x_bits * 2, b, out_dim), dtype=jnp.float32
    )

    kernel = functools.partial(
        _noisy_crossbar_kernel,
        x_bits=x_bits,
        cell_bits=cell_bits,
        full_scale=full_scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((b, n_sum), lambda c: (0, c)),
            pl.BlockSpec((n_sum, out_dim), lambda c: (c, 0)),
            pl.BlockSpec((1, x_bits * 2, b, out_dim), lambda c: (c, 0, 0, 0)),
            pl.BlockSpec((1,), lambda c: (0,)),
        ],
        out_specs=pl.BlockSpec((b, out_dim), lambda c: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, out_dim), jnp.float32),
        interpret=interpret,
    )(x_q, w_q, noise, adc_step)
