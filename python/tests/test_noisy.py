"""Noisy-ADC kernel tests: ENOB semantics (resolution after noise)."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels.crossbar import cim_matmul
from compile.kernels.noisy import cim_matmul_noisy
from compile.kernels import ref


def case(seed, b=8, in_dim=256, out_dim=32, x_bits=4, cell_bits=2):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2**x_bits, (b, in_dim)).astype(np.float32)
    w = rng.integers(0, 2 ** (2 * cell_bits), (in_dim, out_dim)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w)


class TestNoisyCrossbar:
    def test_zero_noise_matches_ideal_kernel(self):
        x, w = case(0)
        step = jnp.asarray([2.0], jnp.float32)
        ideal = cim_matmul(x, w, step, n_sum=128)
        noisy = cim_matmul_noisy(
            x, w, step, jnp.asarray([0.0], jnp.float32), jax.random.PRNGKey(1),
            n_sum=128,
        )
        np.testing.assert_allclose(np.asarray(noisy), np.asarray(ideal), atol=1e-3)

    def test_deterministic_given_key(self):
        x, w = case(1)
        args = (x, w, jnp.asarray([1.0], jnp.float32), jnp.asarray([3.0], jnp.float32))
        a = cim_matmul_noisy(*args, jax.random.PRNGKey(7), n_sum=128)
        b = cim_matmul_noisy(*args, jax.random.PRNGKey(7), n_sum=128)
        c = cim_matmul_noisy(*args, jax.random.PRNGKey(8), n_sum=128)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.any(np.asarray(a) != np.asarray(c))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), sigma=st.floats(0.5, 8.0))
    def test_noise_degrades_sqnr(self, seed, sigma):
        x, w = case(seed)
        step = jnp.asarray([1.0], jnp.float32)
        exact = ref.exact_matmul_ref(x, w)
        clean = cim_matmul(x, w, step, n_sum=128)
        noisy = cim_matmul_noisy(
            x, w, step, jnp.asarray([sigma], jnp.float32), jax.random.PRNGKey(seed),
            n_sum=128,
        )
        assert float(ref.sqnr_db(exact, noisy)) < float(ref.sqnr_db(exact, clean))

    def test_effective_enob_follows_noise_composition(self):
        """Measured ENOB tracks the quantization+noise power composition.

        With a fine quantizer (step 1) and per-read noise sigma, the error
        power per output is ~ n_reads * sigma^2 (noise dominates
        quantization). Effective ENOB = (SQNR - 1.76)/6.02 must fall with
        sigma at ~1 bit per doubling once noise dominates.
        """
        x, w = case(42, b=16)
        step = jnp.asarray([1.0], jnp.float32)
        exact = ref.exact_matmul_ref(x, w)
        enobs = []
        for sigma in [2.0, 4.0, 8.0]:
            y = cim_matmul_noisy(
                x, w, step, jnp.asarray([sigma], jnp.float32), jax.random.PRNGKey(3),
                n_sum=128,
            )
            sqnr = float(ref.sqnr_db(exact, y))
            enobs.append((sqnr - 1.76) / 6.02)
        drops = [a - b for a, b in zip(enobs, enobs[1:])]
        for d in drops:
            assert 0.6 < d < 1.4, f"ENOB drop per noise doubling: {drops} ({enobs})"
