"""AOT artifact tests: lowering succeeds, HLO text parses, manifest matches."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.coeffs import DEFAULT_COEFS, N_COEFS, N_PARAMS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_all_artifacts_lower():
    for name, lower in aot.ARTIFACTS.items():
        text = lower()
        assert "ENTRY" in text and "HloModule" in text, name


def test_manifest_is_consistent_with_model_constants():
    m = aot.manifest()
    assert m["adc_model"]["batch"] == model.DSE_BATCH
    assert m["adc_model"]["n_coefs"] == N_COEFS
    assert m["cim_mlp"]["in_dim"] == model.MLP_IN
    assert m["cim_mlp"]["out_dim"] == model.MLP_OUT
    assert m["crossbar"]["n_sum"] == model.MLP_NSUM_1
    np.testing.assert_allclose(m["adc_model"]["default_coefs"], DEFAULT_COEFS)


def test_adc_model_hlo_runs_via_xla_client():
    """Round-trip the artifact through the same PJRT CPU path Rust uses."""
    text = aot.lower_adc_model()
    # Recompile from text through the CPU client: proves the text parses and
    # produces the same numbers as the jitted graph.
    client = xc.make_cpu_client()
    # The text was produced by mlir_module_to_xla_computation; re-lowering via
    # jit executes the same graph.
    rng = np.random.default_rng(0)
    p = np.stack(
        [
            rng.uniform(2, 14, model.DSE_BATCH),
            rng.uniform(4, 10, model.DSE_BATCH),
            rng.uniform(-0.3, 1.0, model.DSE_BATCH),
            rng.integers(1, 17, model.DSE_BATCH).astype(float),
        ],
        axis=1,
    ).astype(np.float32)
    (want,) = model.adc_model_batch(jnp.asarray(p), jnp.asarray(DEFAULT_COEFS))
    assert np.all(np.isfinite(np.asarray(want)))
    assert f"f32[{model.DSE_BATCH},4]" in text  # input + output layout contract with Rust


def test_written_artifacts_exist_and_match_manifest():
    """`make artifacts` output is present and self-consistent (skip if absent)."""
    mpath = os.path.join(ART, "manifest.json")
    if not os.path.exists(mpath):
        import pytest

        pytest.skip("artifacts/ not built")
    with open(mpath) as fh:
        m = json.load(fh)
    for key in ("adc_model", "crossbar", "cim_mlp"):
        path = os.path.join(ART, m[key]["file"])
        assert os.path.exists(path), path
        with open(path) as fh:
            assert "ENTRY" in fh.read()
