"""Kernel-vs-reference correctness: the core L1 signal.

Hypothesis sweeps shapes, dtypes-of-content (integer ranges), and model
parameters; every case asserts the Pallas kernel (interpret=True) matches
the pure-jnp oracle.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.coeffs import DEFAULT_COEFS, N_COEFS
from compile.kernels.adc_model import BLOCK, adc_model
from compile.kernels.crossbar import cim_matmul
from compile.kernels import ref


def random_params(rng, n):
    """Design points spanning the paper's evaluation ranges."""
    return np.stack(
        [
            rng.uniform(1.0, 16.0, n),      # ENOB
            rng.uniform(3.0, 10.6, n),      # log10 f: 1e3 .. 4e10 conv/s
            rng.uniform(-0.3, 1.25, n),     # log10(T/32): 16nm .. 570nm
            rng.integers(1, 64, n).astype(np.float64),
        ],
        axis=1,
    ).astype(np.float32)


# ---------------------------------------------------------------------------
# adc_model kernel
# ---------------------------------------------------------------------------

class TestAdcModelKernel:
    def test_matches_ref_default_coefs(self):
        rng = np.random.default_rng(1)
        p = random_params(rng, 2 * BLOCK)
        out = np.asarray(adc_model(jnp.asarray(p), jnp.asarray(DEFAULT_COEFS)))
        expect = np.asarray(ref.adc_model_ref(jnp.asarray(p), jnp.asarray(DEFAULT_COEFS)))
        np.testing.assert_allclose(out, expect, rtol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        blocks=st.integers(1, 4),
        coef_jitter=st.floats(-0.2, 0.2),
    )
    def test_matches_ref_swept(self, seed, blocks, coef_jitter):
        rng = np.random.default_rng(seed)
        p = random_params(rng, blocks * BLOCK)
        coefs = (DEFAULT_COEFS + np.float32(coef_jitter)).astype(np.float32)
        out = np.asarray(adc_model(jnp.asarray(p), jnp.asarray(coefs)))
        expect = np.asarray(ref.adc_model_ref(jnp.asarray(p), jnp.asarray(coefs)))
        np.testing.assert_allclose(out, expect, rtol=2e-5)

    def test_rejects_unaligned_batch(self):
        p = np.zeros((BLOCK + 1, 4), np.float32)
        with pytest.raises(ValueError, match="multiple"):
            adc_model(jnp.asarray(p), jnp.asarray(DEFAULT_COEFS))

    def test_energy_is_max_of_bounds(self):
        """Low throughput sits on the flat bound; high sits on the tradeoff."""
        p = np.zeros((BLOCK, 4), np.float32)
        p[:, 0] = 8.0      # ENOB
        p[:, 3] = 1.0      # n_adcs
        p[: BLOCK // 2, 1] = 4.0    # 1e4 conv/s — deep in the flat region
        p[BLOCK // 2 :, 1] = 10.0   # 1e10 conv/s — deep in the tradeoff region
        out = np.asarray(adc_model(jnp.asarray(p), jnp.asarray(DEFAULT_COEFS)))
        low, high = out[: BLOCK // 2, 0], out[BLOCK // 2 :, 0]
        assert np.allclose(low, low[0])          # flat: no throughput dependence
        assert high[0] > 50 * low[0]             # tradeoff: much higher energy

    def test_power_and_total_area_scale_with_n_adcs(self):
        p = np.zeros((BLOCK, 4), np.float32)
        p[:, 0], p[:, 1], p[:, 2] = 7.0, 8.0, 0.0
        p[:, 3] = np.arange(1, BLOCK + 1, dtype=np.float32)
        out = np.asarray(adc_model(jnp.asarray(p), jnp.asarray(DEFAULT_COEFS)))
        # per-ADC metrics constant; totals linear in n_adcs
        assert np.allclose(out[:, 0], out[0, 0], rtol=1e-6)
        assert np.allclose(out[:, 1], out[0, 1], rtol=1e-6)
        np.testing.assert_allclose(out[:, 2] / out[0, 2], p[:, 3], rtol=1e-5)
        np.testing.assert_allclose(out[:, 3] / out[0, 3], p[:, 3], rtol=1e-5)


# ---------------------------------------------------------------------------
# crossbar kernel
# ---------------------------------------------------------------------------

def random_crossbar_case(rng, b, in_dim, out_dim, x_bits, cell_bits):
    x = rng.integers(0, 2**x_bits, (b, in_dim)).astype(np.float32)
    w = rng.integers(0, 2 ** (2 * cell_bits), (in_dim, out_dim)).astype(np.float32)
    return x, w


class TestCrossbarKernel:
    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        b=st.sampled_from([1, 4, 8, 16]),
        chunks=st.integers(1, 4),
        n_sum=st.sampled_from([16, 32, 64, 128]),
        out_dim=st.sampled_from([8, 16, 64]),
        x_bits=st.integers(1, 5),
        cell_bits=st.integers(1, 3),
        step=st.floats(0.5, 16.0),
    )
    def test_matches_ref_swept(
        self, seed, b, chunks, n_sum, out_dim, x_bits, cell_bits, step
    ):
        rng = np.random.default_rng(seed)
        in_dim = chunks * n_sum
        x, w = random_crossbar_case(rng, b, in_dim, out_dim, x_bits, cell_bits)
        got = np.asarray(
            cim_matmul(
                jnp.asarray(x), jnp.asarray(w), jnp.asarray([step], dtype=np.float32),
                n_sum=n_sum, x_bits=x_bits, cell_bits=cell_bits,
            )
        )
        want = np.asarray(
            ref.cim_matmul_ref(jnp.asarray(x), jnp.asarray(w), n_sum, x_bits,
                               cell_bits, np.float32(step))
        )
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-3)

    def test_fine_step_recovers_exact_matmul(self):
        """With step=1 (ideal ADC) and no clipping, the CiM path is lossless."""
        rng = np.random.default_rng(7)
        x, w = random_crossbar_case(rng, 8, 256, 32, 4, 2)
        got = np.asarray(
            cim_matmul(jnp.asarray(x), jnp.asarray(w),
                       jnp.asarray([1.0], np.float32), n_sum=128)
        )
        exact = np.asarray(ref.exact_matmul_ref(jnp.asarray(x), jnp.asarray(w)))
        np.testing.assert_allclose(got, exact, rtol=0, atol=1e-2)

    def test_coarser_adc_monotonically_degrades_sqnr(self):
        """Doubling the ADC step must not improve SQNR (paper's ENOB knob)."""
        rng = np.random.default_rng(11)
        x, w = random_crossbar_case(rng, 16, 256, 32, 4, 2)
        exact = ref.exact_matmul_ref(jnp.asarray(x), jnp.asarray(w))
        sqnrs = []
        for step in [1.0, 2.0, 4.0, 8.0, 16.0]:
            y = cim_matmul(jnp.asarray(x), jnp.asarray(w),
                           jnp.asarray([step], np.float32), n_sum=128)
            sqnrs.append(float(ref.sqnr_db(exact, y)))
        assert all(a >= b - 1e-6 for a, b in zip(sqnrs, sqnrs[1:])), sqnrs

    def test_rejects_bad_n_sum(self):
        x = np.zeros((4, 100), np.float32)
        w = np.zeros((100, 8), np.float32)
        with pytest.raises(ValueError, match="multiple"):
            cim_matmul(jnp.asarray(x), jnp.asarray(w),
                       jnp.asarray([1.0], np.float32), n_sum=64)

    def test_zero_weights_give_zero_output(self):
        x = np.full((4, 128), 3.0, np.float32)
        w = np.zeros((128, 8), np.float32)
        y = np.asarray(cim_matmul(jnp.asarray(x), jnp.asarray(w),
                                  jnp.asarray([2.0], np.float32), n_sum=64))
        assert np.all(y == 0.0)
