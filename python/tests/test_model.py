"""Layer-2 graph tests: shapes, requantization, MLP composition."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.coeffs import DEFAULT_COEFS, N_METRICS, N_PARAMS
from compile.kernels import ref


def mlp_inputs(seed=0):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2**model.X_BITS, (model.MLP_BATCH, model.MLP_IN))
    w1 = rng.integers(0, 2 ** (2 * model.CELL_BITS), (model.MLP_IN, model.MLP_HIDDEN))
    w2 = rng.integers(0, 2 ** (2 * model.CELL_BITS), (model.MLP_HIDDEN, model.MLP_OUT))
    return (x.astype(np.float32), w1.astype(np.float32), w2.astype(np.float32))


class TestAdcModelBatch:
    def test_shape_and_tuple(self):
        p = np.zeros((model.DSE_BATCH, N_PARAMS), np.float32)
        p[:, 0], p[:, 1], p[:, 3] = 8.0, 8.0, 1.0
        (out,) = model.adc_model_batch(jnp.asarray(p), jnp.asarray(DEFAULT_COEFS))
        assert out.shape == (model.DSE_BATCH, N_METRICS)
        assert np.all(np.isfinite(np.asarray(out)))


class TestRequantize:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 2.0))
    def test_range_and_integrality(self, seed, scale):
        rng = np.random.default_rng(seed)
        y = rng.uniform(-50, 5000, (8, 16)).astype(np.float32)
        q = np.asarray(model.requantize(jnp.asarray(y), scale))
        assert q.min() >= 0.0
        assert q.max() <= 2**model.X_BITS - 1
        np.testing.assert_allclose(q, np.round(q))

    def test_negative_inputs_clamp_to_zero(self):
        q = np.asarray(model.requantize(jnp.asarray(-np.ones((2, 2), np.float32)), 1.0))
        assert np.all(q == 0.0)


class TestCimMlp:
    def test_shapes(self):
        x, w1, w2 = mlp_inputs()
        (logits,) = model.cim_mlp(
            jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w2),
            jnp.asarray([1.0], np.float32), jnp.asarray([1.0], np.float32),
            jnp.asarray([0.01], np.float32),
        )
        assert logits.shape == (model.MLP_BATCH, model.MLP_OUT)
        assert np.all(np.isfinite(np.asarray(logits)))

    def test_matches_composed_reference(self):
        """The full MLP graph == ref crossbar -> requantize -> ref crossbar."""
        x, w1, w2 = mlp_inputs(3)
        step1, step2, scale1 = 1.0, 1.0, 0.02
        (got,) = model.cim_mlp(
            jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w2),
            jnp.asarray([step1], np.float32), jnp.asarray([step2], np.float32),
            jnp.asarray([scale1], np.float32),
        )
        h = ref.cim_matmul_ref(jnp.asarray(x), jnp.asarray(w1), model.MLP_NSUM_1,
                               model.X_BITS, model.CELL_BITS, step1)
        h_q = model.requantize(h, scale1)
        want = ref.cim_matmul_ref(h_q, jnp.asarray(w2), model.MLP_NSUM_2,
                                  model.X_BITS, model.CELL_BITS, step2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-3)

    def test_zero_padded_classes_stay_zero(self):
        """Weight columns for padded classes are zero => logits exactly zero."""
        x, w1, w2 = mlp_inputs(5)
        w2[:, 10:] = 0.0
        (logits,) = model.cim_mlp(
            jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w2),
            jnp.asarray([1.0], np.float32), jnp.asarray([1.0], np.float32),
            jnp.asarray([0.02], np.float32),
        )
        assert np.all(np.asarray(logits)[:, 10:] == 0.0)
