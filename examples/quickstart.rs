//! Quickstart: model one ADC, tune it to a published design point, and
//! interpolate — the §I capability prior work lacked ("7-bit, 65 nm, vary
//! throughput from 1e6 to 1e9 converts per second").
//!
//! Run with: `cargo run --release --example quickstart`

use cimdse::adc::tuning::TuningPoint;
use cimdse::adc::{AdcModel, AdcQuery, fit_model};
use cimdse::survey::generator::{SurveyConfig, generate_survey};
use cimdse::util::units::{fmt_area_um2, fmt_energy_pj, fmt_throughput};

fn main() -> cimdse::Result<()> {
    // 1. Fit the model to the (synthetic) ADC survey — the Fig. 1 pipeline.
    let survey = generate_survey(&SurveyConfig::default());
    let report = fit_model(&survey)?;
    let model = AdcModel::new(report.coefs);
    println!(
        "fitted model over {} survey records (area r = {:.2})\n",
        report.n_records, report.area_r_energy
    );

    // 2. Evaluate an architecture-level query: the paper's example design
    //    point, a 7-bit ADC at 1e9 converts/s in 32 nm.
    let q = AdcQuery { enob: 7.0, total_throughput: 1e9, tech_nm: 32.0, n_adcs: 1 };
    let m = model.eval(&q);
    println!("7-bit, 32 nm, 1 GS/s (model best-case):");
    println!("  energy/convert = {}", fmt_energy_pj(m.energy_pj_per_convert));
    println!("  area           = {}\n", fmt_area_um2(m.area_um2_per_adc));

    // 3. Tune the model to a specific published ADC (§II: "users may tune
    //    the tool ... to match the ADC of interest").
    let reference = TuningPoint {
        query: q,
        energy_pj_per_convert: 2.5, // the ADC we want to model
        area_um2: Some(4.2e4),
    };
    let tuned = model.tuned_to(&reference);
    println!("tuned to a published 7-bit ADC (2.5 pJ/convert, 0.042 mm²):");
    println!(
        "  model now reproduces it exactly: {} / {}\n",
        fmt_energy_pj(tuned.energy_pj_per_convert(&q)),
        fmt_area_um2(tuned.area_um2_per_adc(&q))
    );

    // 4. Interpolate: how would *that* ADC change at 65 nm across three
    //    decades of throughput? (the thing a fixed design point cannot do)
    println!("interpolation at 65 nm, 7-bit, tuned ADC:");
    println!("  {:>14}  {:>14}  {:>12}", "throughput", "energy/convert", "area");
    for exp in [6.0, 7.0, 8.0, 8.5, 9.0] {
        let f = 10f64.powf(exp);
        let qi = AdcQuery { enob: 7.0, total_throughput: f, tech_nm: 65.0, n_adcs: 1 };
        println!(
            "  {:>14}  {:>14}  {:>12}",
            fmt_throughput(f),
            fmt_energy_pj(tuned.energy_pj_per_convert(&qi)),
            fmt_area_um2(tuned.area_um2_per_adc(&qi))
        );
    }
    println!(
        "\nknee (tradeoff bound takes over) at {} for this ENOB/node",
        fmt_throughput(tuned.crossover_throughput(7.0, 65.0))
    );
    Ok(())
}
