//! End-to-end driver (DESIGN.md §5): run the full modeling pipeline on a
//! real workload — all 21 ResNet18 layers mapped onto the four RAELLA
//! parameterizations — and report the paper's headline result (Fig. 4)
//! plus per-layer breakdowns, whole-network area, and ADC-bound
//! latency/throughput.
//!
//! Pipeline exercised: survey → fit → ADC model → architecture presets →
//! mapper → component rollup → report. Results are recorded in
//! EXPERIMENTS.md.
//!
//! Run with: `cargo run --release --example resnet18_raella`

use cimdse::adc::{AdcModel, fit_model};
use cimdse::arch::raella::{RaellaVariant, raella};
use cimdse::dse::figures;
use cimdse::energy::{AreaScope, accel_area, workload_energy};
use cimdse::mapper::{arrays_for_workload, map_layer};
use cimdse::report::Table;
use cimdse::survey::generator::{SurveyConfig, generate_survey};
use cimdse::util::units::{fmt_area_um2, fmt_energy_pj};
use cimdse::workload::resnet18::resnet18;

fn main() -> cimdse::Result<()> {
    // --- fit the ADC model from the survey (Fig. 1 pipeline) -------------
    let survey = generate_survey(&SurveyConfig::default());
    let report = fit_model(&survey)?;
    let model = AdcModel::new(report.coefs);
    let net = resnet18();
    println!(
        "== ResNet18 ({} layers, {:.2} GMACs) on RAELLA S/M/L/XL ==\n",
        net.layers.len(),
        net.total_macs() as f64 / 1e9
    );

    // --- the paper's Fig. 4 ----------------------------------------------
    println!("Fig. 4 reproduction (energy per inference):");
    println!("{}", figures::render_fig4(&figures::fig4(&model)?).render());

    // --- whole-network summary per variant --------------------------------
    let mut t = Table::new(vec![
        "variant",
        "energy/inf",
        "ADC E%",
        "arrays",
        "area",
        "ADC A%",
        "latency (ms)",
        "inf/s",
    ]);
    for variant in RaellaVariant::ALL {
        let arch = raella(variant);
        let e = workload_energy(&arch, &model, &net)?;
        let arrays = arrays_for_workload(&arch, &net.layers);
        let a = accel_area(&arch, &model, AreaScope::Tile { n_arrays: arrays });
        // ADC-bound latency: layers run sequentially on their arrays.
        let latency_s: f64 = net
            .layers
            .iter()
            .map(|l| map_layer(&arch, l).map(|m| m.latency_s).unwrap_or(0.0))
            .sum();
        t.row(vec![
            variant.name().to_string(),
            fmt_energy_pj(e.total_pj()),
            format!("{:.0}%", 100.0 * e.adc_fraction()),
            arrays.to_string(),
            fmt_area_um2(a.total_um2()),
            format!("{:.0}%", 100.0 * a.adc_fraction()),
            format!("{:.2}", latency_s * 1e3),
            format!("{:.1}", 1.0 / latency_s),
        ]);
    }
    println!("whole-network rollup:\n{}", t.render());

    // --- per-layer detail for the best variant -----------------------------
    let rows = figures::fig4(&model)?;
    let best = rows
        .iter()
        .filter(|r| r.group == "all-layers")
        .min_by(|a, b| a.total_pj.total_cmp(&b.total_pj))
        .unwrap();
    println!(
        "best overall variant: {} ({} per inference) — paper predicts M or L\n",
        best.variant,
        fmt_energy_pj(best.total_pj)
    );
    let variant = RaellaVariant::ALL
        .into_iter()
        .find(|v| v.name() == best.variant)
        .unwrap();
    println!("per-layer breakdown on {}:", raella(variant).name);
    println!("{}", figures::per_layer_table(&model, &raella(variant), &net)?.render());
    Ok(())
}
