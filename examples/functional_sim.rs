//! Functional CiM simulation through the compiled Pallas crossbar:
//! prove the datapath the energy model prices actually computes, and
//! measure how ADC resolution (the paper's central knob) trades off
//! against computational fidelity — on a real small workload served
//! entirely through PJRT (three layers composed: Pallas kernel → JAX
//! graph → Rust runtime).
//!
//! Workload: 10-class synthetic 16x16 "digit" prototypes; batched
//! classification where the 256→64 crossbar holds the class prototypes in
//! its first 10 columns. We report CiM-vs-exact argmax agreement,
//! accuracy vs ground truth, SQNR per ADC step, and PJRT inference
//! latency/throughput.
//!
//! Run with: `cargo run --release --example functional_sim`
//! (requires `make artifacts`)

use std::time::Instant;

use cimdse::runtime::{CimMlpEngine, CrossbarEngine, Manifest};
use cimdse::report::Table;
use cimdse::util::Rng;

/// Deterministic 10-class prototype patterns over 16x16, values 0..15.
fn make_prototypes(rng: &mut Rng) -> Vec<Vec<f32>> {
    (0..10)
        .map(|class| {
            (0..256)
                .map(|i| {
                    let (row, col) = (i / 16, i % 16);
                    // Class-specific diagonal bands + per-class phase.
                    let phase = (row * (class + 2) + col * (11 - class)) % 16;
                    let base = if phase < 5 { 12.0 } else { 2.0 };
                    (base + rng.uniform(-1.0, 1.0)).clamp(0.0, 15.0).round() as f32
                })
                .collect()
        })
        .collect()
}

/// A noisy sample of a prototype (pixel noise + random pixel dropout).
fn sample_of(proto: &[f32], rng: &mut Rng, noise: f64) -> Vec<f32> {
    proto
        .iter()
        .map(|&p| {
            let v = p as f64 + rng.normal(0.0, noise);
            if rng.bool(0.05) { 0.0 } else { v.clamp(0.0, 15.0).round() as f32 }
        })
        .collect()
}

fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap()
}

fn main() -> cimdse::Result<()> {
    let manifest = Manifest::locate()?;
    let crossbar = CrossbarEngine::load(&manifest)?;
    let (b, in_dim, out_dim) = crossbar.shape;
    println!(
        "crossbar artifact: [{b}, {in_dim}] x [{in_dim}, {out_dim}], analog sum {} rows\n",
        crossbar.n_sum
    );

    // --- weights: class prototypes in the first 10 columns ----------------
    let mut rng = Rng::new(2024);
    let protos = make_prototypes(&mut rng);
    let mut w = vec![0f32; in_dim * out_dim];
    for (class, proto) in protos.iter().enumerate() {
        for (r, &v) in proto.iter().enumerate() {
            // Store prototype (quantized to the 4-bit cell pair range).
            w[r * out_dim + class] = v;
        }
    }

    // --- batched classification at several ADC resolutions ----------------
    // ADC step in analog-sum units: step = full_scale / 2^bits.
    let full_scale = (crossbar.n_sum * 3) as f32; // 2-bit cells: max 3/row
    let n_batches = 8;
    let mut table = Table::new(vec![
        "ADC bits",
        "step",
        "CiM=exact argmax",
        "accuracy (CiM)",
        "accuracy (exact)",
        "SQNR (dB)",
        "theory (dB)",
    ]);

    for bits in [2u32, 3, 4, 6, 8, 10] {
        let step = full_scale / (1u32 << bits) as f32;
        let mut agree = 0usize;
        let mut correct_cim = 0usize;
        let mut correct_exact = 0usize;
        let mut sig = 0f64;
        let mut err = 0f64;
        let mut total = 0usize;
        let mut case_rng = Rng::new(7_000 + bits as u64);

        for _ in 0..n_batches {
            let labels: Vec<usize> = (0..b).map(|_| case_rng.index(10)).collect();
            let mut x = vec![0f32; b * in_dim];
            for (row, &label) in labels.iter().enumerate() {
                let s = sample_of(&protos[label], &mut case_rng, 6.0);
                x[row * in_dim..(row + 1) * in_dim].copy_from_slice(&s);
            }
            let y = crossbar.run(&x, &w, step)?;
            // Exact integer matmul reference (computed natively).
            for row in 0..b {
                let mut exact = vec![0f32; out_dim];
                for (r, xv) in x[row * in_dim..(row + 1) * in_dim].iter().enumerate() {
                    if *xv == 0.0 {
                        continue;
                    }
                    for (c, e) in exact.iter_mut().enumerate() {
                        *e += xv * w[r * out_dim + c];
                    }
                }
                let cim_row = &y[row * out_dim..row * out_dim + 10];
                let exact_row = &exact[..10];
                let pc = argmax(cim_row);
                let pe = argmax(exact_row);
                agree += usize::from(pc == pe);
                correct_cim += usize::from(pc == labels[row]);
                correct_exact += usize::from(pe == labels[row]);
                for c in 0..out_dim {
                    sig += (exact[c] as f64).powi(2);
                    err += ((exact[c] - y[row * out_dim + c]) as f64).powi(2);
                }
                total += 1;
            }
        }
        let sqnr_db = 10.0 * (sig / err.max(1e-12)).log10();
        // Analytic expectation from the ENOB model (adc::enob): reading a
        // per-bit-plane sum through a uniform quantizer. The signal here is
        // not full-scale, so measured SQNR sits below the ceiling but must
        // track its +12 dB / 2-bit slope.
        let theory_db = cimdse::adc::enob::expected_read_sqnr_db(128, 2, bits as f64);
        table.row(vec![
            bits.to_string(),
            format!("{step:.2}"),
            format!("{:.1}%", 100.0 * agree as f64 / total as f64),
            format!("{:.1}%", 100.0 * correct_cim as f64 / total as f64),
            format!("{:.1}%", 100.0 * correct_exact as f64 / total as f64),
            format!("{sqnr_db:.1}"),
            format!("{theory_db:.1}"),
        ]);
    }
    println!("ADC resolution vs computational fidelity ({} samples/point):", n_batches * b);
    println!("{}", table.render());
    println!(
        "(this is the §III-A energy/fidelity tradeoff seen from the functional side:\n\
         bigger analog sums need more ADC bits to keep the same fidelity)\n"
    );

    // --- PJRT serving latency/throughput ----------------------------------
    let x: Vec<f32> = (0..b * in_dim).map(|_| rng.range(0, 16) as f32).collect();
    let step = full_scale / 64.0;
    // Warm-up, then measure.
    for _ in 0..3 {
        crossbar.run(&x, &w, step)?;
    }
    let iters = 50;
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(crossbar.run(&x, &w, step)?);
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "crossbar layer via PJRT: {:.3} ms/batch ({} samples) = {:.0} samples/s",
        dt * 1e3,
        b,
        b as f64 / dt
    );

    // Full 2-layer MLP artifact (256 -> 64 -> 16).
    let mlp = CimMlpEngine::load(&manifest)?;
    let (mb, mi, mh, mo) = mlp.shape;
    let w1: Vec<f32> = (0..mi * mh).map(|_| rng.range(0, 16) as f32).collect();
    let w2: Vec<f32> = (0..mh * mo).map(|_| rng.range(0, 16) as f32).collect();
    let xm: Vec<f32> = (0..mb * mi).map(|_| rng.range(0, 16) as f32).collect();
    for _ in 0..3 {
        mlp.forward(&xm, &w1, &w2, 1.0, 1.0, 0.002)?;
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(mlp.forward(&xm, &w1, &w2, 1.0, 1.0, 0.002)?);
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "2-layer CiM MLP via PJRT: {:.3} ms/batch ({} samples) = {:.0} samples/s",
        dt * 1e3,
        mb,
        mb as f64 / dt
    );
    Ok(())
}
