//! Fig. 5-style exploration through the AOT-compiled PJRT artifact:
//! "Picking the Number of ADCs for an Architecture".
//!
//! The raw ADC metrics for the (n_adcs x throughput) grid are computed by
//! the compiled Pallas kernel (`artifacts/adc_model.hlo.txt`) — Python is
//! not involved at runtime — then combined with the native accelerator
//! rollup into the EAP table, with a power/area Pareto front on top.
//!
//! Run with: `cargo run --release --example adc_count_exploration`
//! (falls back to the native evaluator when artifacts are missing)

use cimdse::adc::{AdcModel, fit_model};
use cimdse::dse::{
    Evaluator, NativeEvaluator, PjrtEvaluator, SweepSpec, figures, pareto_front, run_sweep,
};
use cimdse::report::Table;
use cimdse::runtime::{AdcModelEngine, Manifest};
use cimdse::survey::generator::{SurveyConfig, generate_survey};
use cimdse::util::units::{fmt_area_um2, fmt_power_w, fmt_throughput};

fn main() -> cimdse::Result<()> {
    let survey = generate_survey(&SurveyConfig::default());
    let model = AdcModel::new(fit_model(&survey)?.coefs);

    // Pick the backend: PJRT artifact if built, else native.
    let evaluator: Box<dyn Evaluator> = match Manifest::locate()
        .and_then(|m| AdcModelEngine::load(&m))
    {
        Ok(engine) => {
            println!("backend: PJRT artifact (adc_model.hlo.txt)\n");
            Box::new(PjrtEvaluator::new(engine, model))
        }
        Err(e) => {
            println!("backend: native ({e})\n");
            Box::new(NativeEvaluator::new(model))
        }
    };

    // --- raw ADC metrics over the Fig. 5 grid, via the artifact ----------
    let spec = SweepSpec::fig5(7.0, 5);
    let evaluated = run_sweep(&spec, evaluator.as_ref())?;
    let mut t = Table::new(vec![
        "total throughput",
        "n_adcs",
        "E/convert (pJ)",
        "ADC power",
        "ADC area",
    ]);
    for p in &evaluated {
        t.row(vec![
            fmt_throughput(p.query.total_throughput),
            p.query.n_adcs.to_string(),
            format!("{:.3}", p.metrics.energy_pj_per_convert),
            fmt_power_w(p.metrics.total_power_w),
            fmt_area_um2(p.metrics.total_area_um2),
        ]);
    }
    println!("ADC metrics on the Fig. 5 grid (7-bit, 32 nm):\n{}", t.render());

    // Pareto front over (power, area): which (throughput, n) corners win.
    let objectives: Vec<(f64, f64)> = evaluated
        .iter()
        .map(|p| (p.metrics.total_power_w, p.metrics.total_area_um2))
        .collect();
    let front = pareto_front(&objectives);
    println!("power/area Pareto-optimal configurations:");
    for &i in &front {
        let p = &evaluated[i];
        println!(
            "  n_adcs={:<2} @ {:<12} power={} area={}",
            p.query.n_adcs,
            fmt_throughput(p.query.total_throughput),
            fmt_power_w(p.metrics.total_power_w),
            fmt_area_um2(p.metrics.total_area_um2)
        );
    }

    // --- full-accelerator EAP (the paper's Fig. 5) ------------------------
    println!("\nFig. 5 reproduction (accelerator EAP on the chosen layer):");
    println!("{}", figures::render_fig5(&figures::fig5(&model, 5)?).render());
    println!(
        "paper's claims: EAP rises with required throughput; the n_adcs choice\n\
         swings EAP ~3x; low-throughput designs favor fewer ADCs, high-throughput more."
    );
    Ok(())
}
