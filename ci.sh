#!/usr/bin/env bash
# Tier-1 CI for the cimdse crate. Mirrors ROADMAP.md's verify line and
# additionally compile-checks every bench and example target.
#
# Usage: ./ci.sh  (from the repo root; no network access required)
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== bench targets compile (all-features preferred, default as fallback) =="
# --all-features exercises the `pjrt` gate against the vendored xla API
# shim; if that shim is ever swapped for real bindings that need system
# libs absent from CI, fall back to the default feature set.
cargo build --benches --all-features || cargo build --benches

echo "== example targets compile =="
cargo build --examples

echo "ci.sh: all green"
