#!/usr/bin/env bash
# Tier-1 CI for the cimdse crate. Mirrors ROADMAP.md's verify line,
# compile-checks every bench and example target, then runs the perf
# hot-path bench in quick mode and validates its BENCH_sweep.json
# trajectory artifact (every PR leaves a comparable perf record).
#
# Usage: ./ci.sh  (from the repo root; no network access required)
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== bench targets compile (all-features preferred, default as fallback) =="
# --all-features exercises the `pjrt` gate against the vendored xla API
# shim; if that shim is ever swapped for real bindings that need system
# libs absent from CI, fall back to the default feature set.
cargo build --benches --all-features || cargo build --benches

echo "== example targets compile =="
cargo build --examples

echo "== perf_hotpaths (quick mode) -> BENCH_sweep.json =="
rm -f BENCH_sweep.json
CIMDSE_BENCH_QUICK=1 cargo bench --bench perf_hotpaths

echo "== validate BENCH_sweep.json =="
# Hard gate: a missing or malformed perf artifact fails CI.
test -s BENCH_sweep.json || { echo "ci.sh: BENCH_sweep.json missing or empty" >&2; exit 1; }
cargo run --quiet --release -- bench-report --path BENCH_sweep.json

echo "ci.sh: all green"
