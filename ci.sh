#!/usr/bin/env bash
# Tier-1 CI for the cimdse crate. Mirrors ROADMAP.md's verify line,
# compile-checks every bench and example target, then runs the perf
# hot-path bench in quick mode and validates its BENCH_sweep.json
# trajectory artifact (every PR leaves a comparable perf record).
#
# Usage: ./ci.sh  (from the repo root; no network access required)
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cimdse lint (static invariant checks, hard fail) =="
# Runs right after the build so invariant violations surface even when a
# later stage is skipped. Rules + suppression syntax: rust/docs/lints.md.
target/release/cimdse lint .
# --json must emit a parsable report with the same zero findings.
target/release/cimdse lint --json . | grep -q '"findings": \[\]' \
  || { echo "ci.sh: lint --json did not report an empty findings array" >&2; exit 1; }

echo "== cargo test -q =="
cargo test -q

echo "== protocol v2 conformance corpus (both cores, byte-compared) =="
# Already part of `cargo test -q`, but the corpus is this PR's protocol
# gate, so run it as its own visible stage: every corpus case replays
# over a real socket against the event-loop AND threaded cores and the
# response bytes are cmp'd, plus the v2 battery (hello negotiation,
# progress cadence, cancel live/unknown/completed, cancel-on-disconnect).
cargo test -q --test protocol_corpus

echo "== simd feature leg (x86_64 only) =="
# The `simd` feature compiles the AVX2 lane kernel in util::fastmath
# (docs/numeric_tiers.md). It is a no-op off x86_64 — the cfg gates
# compile it out — so only x86_64 hosts exercise the build+test leg;
# elsewhere we print a notice rather than pretend coverage.
ARCH=$(uname -m)
if [ "$ARCH" = "x86_64" ]; then
  cargo build --release --features simd
  cargo test -q --features simd
else
  echo "ci.sh: SKIP simd leg — host is $ARCH, the AVX2 kernel only compiles on x86_64"
  echo "       (the portable fast-tier batch is covered by the default test run above)"
fi

echo "== bench targets compile (all-features preferred, default as fallback) =="
# --all-features exercises the `pjrt` gate against the vendored xla API
# shim; if that shim is ever swapped for real bindings that need system
# libs absent from CI, fall back to the default feature set.
cargo build --benches --all-features || cargo build --benches

echo "== example targets compile =="
cargo build --examples

echo "== shard/merge round-trip (3 processes vs single process, bit-identical) =="
BIN=target/release/cimdse
SHARD_DIR=$(mktemp -d)
SERVE_PID=""
W1_PID=""
W2_PID=""
trap '{ for P in "$SERVE_PID" "$W1_PID" "$W2_PID"; do [ -n "$P" ] && kill "$P" 2>/dev/null; done; rm -rf "$SHARD_DIR"; } || true' EXIT

# Poll a serve log for the "listening on" banner; prints the address.
serve_addr() {
  local log="$1" pid="$2" addr=""
  for _ in $(seq 1 200); do
    addr=$(sed -n 's/.*listening on \([0-9.:]*\).*/\1/p' "$log" | head -n 1)
    [ -n "$addr" ] && { echo "$addr"; return 0; }
    kill -0 "$pid" 2>/dev/null \
      || { echo "ci.sh: serve died before binding" >&2; cat "$log" >&2; return 1; }
    sleep 0.1
  done
  echo "ci.sh: serve never reported its address" >&2; cat "$log" >&2; return 1
}
SPEC_ARGS=(sweep --spec dense --points 6)
for i in 0 1 2; do
  "$BIN" "${SPEC_ARGS[@]}" --shard "$i/3" --out "$SHARD_DIR/shard_$i.json"
done
"$BIN" merge-shards "$SHARD_DIR"/shard_0.json "$SHARD_DIR"/shard_1.json \
  "$SHARD_DIR"/shard_2.json --out "$SHARD_DIR/merged.json"
"$BIN" "${SPEC_ARGS[@]}" --summary-json "$SHARD_DIR/single.json"
cmp "$SHARD_DIR/merged.json" "$SHARD_DIR/single.json"
echo "merged shards == single-process summary (byte-identical)"

echo "== shard resume (completed artifact skipped, deleted one rebuilt) =="
RESUME_OUT=$("$BIN" "${SPEC_ARGS[@]}" --shard 0/3 --out "$SHARD_DIR/shard_0.json")
echo "$RESUME_OUT" | grep -q "already complete" \
  || { echo "ci.sh: completed shard was not skipped: $RESUME_OUT" >&2; exit 1; }
rm "$SHARD_DIR/shard_1.json"
"$BIN" "${SPEC_ARGS[@]}" --shard 1/3 --out "$SHARD_DIR/shard_1.json"
"$BIN" merge-shards "$SHARD_DIR"/shard_*.json --out "$SHARD_DIR/merged2.json"
cmp "$SHARD_DIR/merged.json" "$SHARD_DIR/merged2.json"
echo "resumed shard set merges identically"

echo "== tri-objective shard/merge round-trip (energy,area,snr; cmp vs single process) =="
# Same grid, tri-objective rollup: 3 shard processes must merge
# byte-identically to the single-process tri summary, and the SNR
# context must enter the fingerprint — a classic artifact of the same
# grid can never slip into a tri merge.
TRI_ARGS=("${SPEC_ARGS[@]}" --objectives energy,area,snr --snr-sum 2048 --snr-cell-bits 3)
for i in 0 1 2; do
  "$BIN" "${TRI_ARGS[@]}" --shard "$i/3" --out "$SHARD_DIR/tri_shard_$i.json"
done
"$BIN" merge-shards "$SHARD_DIR"/tri_shard_0.json "$SHARD_DIR"/tri_shard_1.json \
  "$SHARD_DIR"/tri_shard_2.json --out "$SHARD_DIR/tri_merged.json"
"$BIN" "${TRI_ARGS[@]}" --summary-json "$SHARD_DIR/tri_single.json"
cmp "$SHARD_DIR/tri_merged.json" "$SHARD_DIR/tri_single.json"
grep -q '"snr_front"' "$SHARD_DIR/tri_merged.json" \
  || { echo "ci.sh: tri-objective summary lacks the snr_front payload" >&2; exit 1; }
if "$BIN" merge-shards "$SHARD_DIR"/shard_0.json "$SHARD_DIR"/tri_shard_1.json \
  "$SHARD_DIR"/tri_shard_2.json --out "$SHARD_DIR/tri_mixed.json" 2>/dev/null; then
  echo "ci.sh: merge-shards accepted a classic/tri artifact mix" >&2; exit 1
fi
echo "tri-objective merged shards == single-process tri summary; classic/tri mix refused"

# The classic surface must be untouched by the new flag: naming the
# default objective set byte-matches omitting it.
"$BIN" "${SPEC_ARGS[@]}" --objectives power,area --summary-json "$SHARD_DIR/classic_named.json"
cmp "$SHARD_DIR/single.json" "$SHARD_DIR/classic_named.json"
echo "--objectives power,area == default (byte-identical)"

echo "== serve smoke test (event-loop daemon on an ephemeral port) =="
SERVE_LOG="$SHARD_DIR/serve.log"
"$BIN" serve --addr 127.0.0.1:0 --core event-loop > "$SERVE_LOG" 2>&1 &
SERVE_PID=$!
ADDR=$(serve_addr "$SERVE_LOG" "$SERVE_PID")
echo "daemon at $ADDR"

# Served eval must be byte-identical to the direct `model` subcommand.
EVAL_ARGS=(--enob 7 --throughput 1.3e9 --tech 32 --n-adcs 8)
"$BIN" query --addr "$ADDR" --op eval "${EVAL_ARGS[@]}" > "$SHARD_DIR/served_eval.txt"
"$BIN" model "${EVAL_ARGS[@]}" > "$SHARD_DIR/direct_eval.txt"
diff "$SHARD_DIR/served_eval.txt" "$SHARD_DIR/direct_eval.txt"
echo "served eval == direct model output"
# Second query on the same model: must land a prepared-model cache hit.
"$BIN" query --addr "$ADDR" --op eval "${EVAL_ARGS[@]}" > /dev/null

# Served sweep summary must be byte-identical to `sweep --summary-json`.
"$BIN" query --addr "$ADDR" --op sweep --spec dense --points 5 \
  --out "$SHARD_DIR/served_summary.json"
"$BIN" sweep --spec dense --points 5 --summary-json "$SHARD_DIR/direct_summary.json"
cmp "$SHARD_DIR/served_summary.json" "$SHARD_DIR/direct_summary.json"
echo "served sweep summary == direct summary (byte-identical)"

"$BIN" query --addr "$ADDR" --op metrics | tee "$SHARD_DIR/metrics.txt"
grep -Eq 'cache +[1-9][0-9]* hits' "$SHARD_DIR/metrics.txt" \
  || { echo "ci.sh: expected nonzero cache hits on a repeated model" >&2; exit 1; }

"$BIN" query --addr "$ADDR" --op shutdown
wait "$SERVE_PID" \
  || { echo "ci.sh: serve did not exit cleanly after shutdown" >&2; cat "$SERVE_LOG" >&2; exit 1; }
SERVE_PID=""
grep -q "drained cleanly" "$SERVE_LOG" \
  || { echo "ci.sh: serve log lacks graceful-drain confirmation" >&2; cat "$SERVE_LOG" >&2; exit 1; }
echo "daemon drained cleanly (exit 0)"

echo "== cross-core v1 byte identity (event-loop vs threads, raw socket cmp) =="
# The acceptance bar for the event-loop rewrite: a v1 client must see
# byte-identical frames from both cores. Replay one pipelined burst —
# good eval, unknown op, malformed JSON, sweep — over a raw socket
# (bash /dev/tcp, no client-side rendering) against each core and cmp
# the response bytes. Both daemons use the default model fit, so the
# payloads are deterministic.
"$BIN" serve --addr 127.0.0.1:0 --core event-loop > "$SHARD_DIR/ce.log" 2>&1 &
W1_PID=$!
"$BIN" serve --addr 127.0.0.1:0 --core threads > "$SHARD_DIR/ct.log" 2>&1 &
W2_PID=$!
CE=$(serve_addr "$SHARD_DIR/ce.log" "$W1_PID")
CT=$(serve_addr "$SHARD_DIR/ct.log" "$W2_PID")
BURST=$(cat <<'EOF'
{"op": "eval", "id": 1, "query": {"enob": 7, "total_throughput": 1.3e9, "n_adcs": 8}}
{"op": "frobnicate", "id": 2}
{ not json
{"op": "eval", "id": 4}
{"op": "sweep", "id": 5, "spec": {"enobs": [4, 6], "total_throughputs": [1e9, 2e9], "tech_nms": [32], "n_adcs": [1, 4]}}
EOF
)
for PAIR in "event_loop=$CE" "threads=$CT"; do
  TAG=${PAIR%%=*}; A=${PAIR#*=}
  exec 3<>"/dev/tcp/${A%:*}/${A##*:}"
  printf '%s\n' "$BURST" >&3
  head -n 5 <&3 > "$SHARD_DIR/burst_$TAG.txt"
  exec 3<&- 3>&-
done
cmp "$SHARD_DIR/burst_event_loop.txt" "$SHARD_DIR/burst_threads.txt"
echo "pipelined v1 burst == across cores (byte-identical over raw sockets)"
"$BIN" query --addr "$CE" --op shutdown > /dev/null
"$BIN" query --addr "$CT" --op shutdown > /dev/null
wait "$W1_PID" && wait "$W2_PID" \
  || { echo "ci.sh: a cross-core daemon did not drain cleanly" >&2; exit 1; }
W1_PID=""; W2_PID=""

echo "== observability: traced daemon byte-identity + trace capture =="
# Tracing enabled must not change a single served byte: replay the
# cross-core burst against an event-loop daemon running with
# --trace-out and cmp its responses against the untraced capture
# above. The trace file itself must then parse with the crate's own
# JSON parser — `cimdse trace` hard-fails on any malformed line — and
# the Prometheus exposition must render from the same snapshot.
TRACE_FILE="$SHARD_DIR/serve_trace.ndjson"
"$BIN" serve --addr 127.0.0.1:0 --core event-loop --trace-out "$TRACE_FILE" \
  > "$SHARD_DIR/traced.log" 2>&1 &
SERVE_PID=$!
TADDR=$(serve_addr "$SHARD_DIR/traced.log" "$SERVE_PID")
exec 3<>"/dev/tcp/${TADDR%:*}/${TADDR##*:}"
printf '%s\n' "$BURST" >&3
head -n 5 <&3 > "$SHARD_DIR/burst_traced.txt"
exec 3<&- 3>&-
cmp "$SHARD_DIR/burst_event_loop.txt" "$SHARD_DIR/burst_traced.txt"
echo "traced daemon responses == untraced responses (byte-identical)"
"$BIN" query --addr "$TADDR" --op metrics --format prometheus > "$SHARD_DIR/prom.txt"
grep -q '^cimdse_request_duration_seconds_bucket{le="+Inf"}' "$SHARD_DIR/prom.txt" \
  || { echo "ci.sh: prometheus exposition lacks the latency histogram" >&2; exit 1; }
grep -q '^cimdse_error_frames_total' "$SHARD_DIR/prom.txt" \
  || { echo "ci.sh: prometheus exposition lacks error_frames" >&2; exit 1; }
"$BIN" query --addr "$TADDR" --op shutdown > /dev/null
wait "$SERVE_PID" \
  || { echo "ci.sh: traced daemon did not drain cleanly" >&2; cat "$SHARD_DIR/traced.log" >&2; exit 1; }
SERVE_PID=""
test -s "$TRACE_FILE" || { echo "ci.sh: trace file missing or empty" >&2; exit 1; }
"$BIN" trace "$TRACE_FILE" | tee "$SHARD_DIR/trace_report.txt"
grep -q "cimdse trace:" "$SHARD_DIR/trace_report.txt" \
  || { echo "ci.sh: trace analyzer produced no report" >&2; exit 1; }
echo "trace file parses and analyzes"

echo "== distributed sweep over 2 local workers (event-loop core, cmp vs single process) =="
# Each process records its own trace file; the launcher propagates its
# shard-span contexts to the workers over the protocol `trace` field,
# so the three files concatenate into one connected trace forest
# (analyzed after the summary cmp below).
"$BIN" serve --addr 127.0.0.1:0 --core event-loop --trace-out "$SHARD_DIR/w1_trace.ndjson" > "$SHARD_DIR/w1.log" 2>&1 &
W1_PID=$!
"$BIN" serve --addr 127.0.0.1:0 --core event-loop --trace-out "$SHARD_DIR/w2_trace.ndjson" > "$SHARD_DIR/w2.log" 2>&1 &
W2_PID=$!
A1=$(serve_addr "$SHARD_DIR/w1.log" "$W1_PID")
A2=$(serve_addr "$SHARD_DIR/w2.log" "$W2_PID")
echo "workers at $A1 and $A2"
DIST_ARGS=(sweep --spec dense --points 6 --workers "$A1,$A2" --shards 6 \
  --out "$SHARD_DIR/dist" --summary-json "$SHARD_DIR/dist_summary.json" \
  --trace-out "$SHARD_DIR/launch_trace.ndjson")
"$BIN" "${DIST_ARGS[@]}" | tee "$SHARD_DIR/dist.txt"
"$BIN" sweep --spec dense --points 6 --summary-json "$SHARD_DIR/dist_single.json"
cmp "$SHARD_DIR/dist_summary.json" "$SHARD_DIR/dist_single.json"
echo "distributed summary == single-process summary (byte-identical)"

# Fleet trace forest: the launcher's trace plus both workers' traces
# concatenate into one NDJSON file, and the analyzer must see all three
# processes — the launcher by label, each worker by its bound address
# (proof the trace context actually crossed the wire to both).
cat "$SHARD_DIR/launch_trace.ndjson" "$SHARD_DIR/w1_trace.ndjson" \
  "$SHARD_DIR/w2_trace.ndjson" > "$SHARD_DIR/fleet_trace.ndjson"
"$BIN" trace "$SHARD_DIR/fleet_trace.ndjson" | tee "$SHARD_DIR/fleet_report.txt"
for P in launcher "$A1" "$A2"; do
  grep -q "$P" "$SHARD_DIR/fleet_report.txt" \
    || { echo "ci.sh: fleet trace report is missing process $P" >&2; exit 1; }
done
echo "fleet trace stitches launcher + both workers into one forest"

# Both workers must have served at least one shard (the affinity
# scheduler guarantees a healthy worker is never starved) — asserted
# through each daemon's own `metrics` op.
for A in "$A1" "$A2"; do
  "$BIN" query --addr "$A" --op metrics | grep -Eq 'shard [1-9]' \
    || { echo "ci.sh: worker $A served no shard requests" >&2; exit 1; }
done
echo "both workers served >= 1 shard (metrics op)"

# Resume: with every artifact on disk, a re-run computes nothing — it
# must succeed even though both worker addresses are now dead.
"$BIN" query --addr "$A1" --op shutdown
"$BIN" query --addr "$A2" --op shutdown
wait "$W1_PID" && wait "$W2_PID" \
  || { echo "ci.sh: a worker did not drain cleanly" >&2; exit 1; }
W1_PID=""; W2_PID=""
RESUME_OUT=$("$BIN" "${DIST_ARGS[@]/dist_summary/dist_summary2}")
echo "$RESUME_OUT" | grep -q "0 computed, 6 resumed" \
  || { echo "ci.sh: distributed resume did not skip completed shards: $RESUME_OUT" >&2; exit 1; }
cmp "$SHARD_DIR/dist_summary.json" "$SHARD_DIR/dist_summary2.json"
echo "distributed resume skipped all shards and merged identically"

echo "== quick 64-client soak (event-loop daemon, process level) =="
# 64 concurrent real client processes against one event-loop daemon,
# then a graceful drain — the process-level cut of the 256-connection
# in-process soak in tests/async_core.rs. Every client must exit 0 and
# the daemon must still drain cleanly afterwards.
SOAK_LOG="$SHARD_DIR/soak.log"
"$BIN" serve --addr 127.0.0.1:0 --core event-loop > "$SOAK_LOG" 2>&1 &
SERVE_PID=$!
SOAK_ADDR=$(serve_addr "$SOAK_LOG" "$SERVE_PID")
QPIDS=()
for i in $(seq 1 64); do
  "$BIN" query --addr "$SOAK_ADDR" --op eval \
    --enob $((3 + i % 10)) --throughput 1.3e9 --n-adcs $((1 + i % 4)) \
    > /dev/null &
  QPIDS+=($!)
done
for P in "${QPIDS[@]}"; do
  wait "$P" || { echo "ci.sh: a soak client failed" >&2; exit 1; }
done
"$BIN" query --addr "$SOAK_ADDR" --op metrics | grep -Eq 'requests +(6[4-9]|[7-9][0-9]|[1-9][0-9]{2,}) total' \
  || { echo "ci.sh: soak daemon reports fewer than 64 requests" >&2; exit 1; }
"$BIN" query --addr "$SOAK_ADDR" --op shutdown > /dev/null
wait "$SERVE_PID" \
  || { echo "ci.sh: soak daemon did not exit cleanly" >&2; cat "$SOAK_LOG" >&2; exit 1; }
SERVE_PID=""
grep -q "drained cleanly" "$SOAK_LOG" \
  || { echo "ci.sh: soak daemon lacks graceful-drain confirmation" >&2; cat "$SOAK_LOG" >&2; exit 1; }
echo "64 concurrent clients served, daemon drained cleanly"

echo "== bench_serve (quick mode, both cores, 1/4/16/64 clients) -> BENCH_serve.json =="
rm -f BENCH_serve.json
CIMDSE_BENCH_QUICK=1 cargo bench --bench bench_serve
test -s BENCH_serve.json || { echo "ci.sh: BENCH_serve.json missing or empty" >&2; exit 1; }
cargo run --quiet --release -- bench-report --path BENCH_serve.json

echo "== perf_hotpaths (quick mode) -> BENCH_sweep.json =="
rm -f BENCH_sweep.json
CIMDSE_BENCH_QUICK=1 cargo bench --bench perf_hotpaths

echo "== validate BENCH_sweep.json =="
# Hard gate: a missing or malformed perf artifact fails CI. bench-report
# rejects anything but schema 2 (which carries the `tiers` table), so a
# stale artifact from an older binary also fails here.
test -s BENCH_sweep.json || { echo "ci.sh: BENCH_sweep.json missing or empty" >&2; exit 1; }
cargo run --quiet --release -- bench-report --path BENCH_sweep.json

echo "== perf_hotpaths with --features simd (x86_64 only) -> BENCH_sweep_simd.json =="
# Second quick bench with the AVX2 kernel compiled in, written next to
# the portable-tier artifact so both tiers leave a validated record.
if [ "$ARCH" = "x86_64" ]; then
  rm -f BENCH_sweep_simd.json
  CIMDSE_BENCH_QUICK=1 CIMDSE_BENCH_OUT=BENCH_sweep_simd.json \
    cargo bench --bench perf_hotpaths --features simd
  test -s BENCH_sweep_simd.json \
    || { echo "ci.sh: BENCH_sweep_simd.json missing or empty" >&2; exit 1; }
  cargo run --quiet --release -- bench-report --path BENCH_sweep_simd.json
else
  echo "ci.sh: SKIP simd bench — host is $ARCH (see simd leg above)"
fi

echo "== miri (nightly-only, auto-skips when the toolchain is absent) =="
# Miri interprets the exec unit tests (the crate's only unsafe code:
# the chunk-claim fast path) and catches UB that normal tests cannot.
if command -v rustup >/dev/null 2>&1 \
   && rustup toolchain list 2>/dev/null | grep -q nightly \
   && rustup component list --toolchain nightly 2>/dev/null \
      | grep -q 'miri.*(installed)'; then
  cargo +nightly miri test --lib exec
else
  echo "ci.sh: SKIP miri — needs rustup + a nightly toolchain with the miri component"
  echo "       (install: rustup toolchain install nightly && rustup +nightly component add miri)"
fi

echo "== ThreadSanitizer (nightly-only, auto-skips when unavailable) =="
# TSan instruments the serve round-trip test, the most concurrent path
# (daemon threads + client connections over one state mutex).
if command -v rustup >/dev/null 2>&1 \
   && rustup toolchain list 2>/dev/null | grep -q nightly \
   && rustup component list --toolchain nightly 2>/dev/null \
      | grep -q 'rust-src.*(installed)'; then
  RUSTFLAGS="-Zsanitizer=thread" cargo +nightly test -Z build-std \
    --target "$(rustc -vV | sed -n 's/^host: //p')" --test serve_roundtrip
else
  echo "ci.sh: SKIP tsan — needs rustup + a nightly toolchain with rust-src"
  echo "       (install: rustup toolchain install nightly && rustup +nightly component add rust-src)"
fi

echo "ci.sh: all green"
