#!/usr/bin/env bash
# Tier-1 CI for the cimdse crate. Mirrors ROADMAP.md's verify line,
# compile-checks every bench and example target, then runs the perf
# hot-path bench in quick mode and validates its BENCH_sweep.json
# trajectory artifact (every PR leaves a comparable perf record).
#
# Usage: ./ci.sh  (from the repo root; no network access required)
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== bench targets compile (all-features preferred, default as fallback) =="
# --all-features exercises the `pjrt` gate against the vendored xla API
# shim; if that shim is ever swapped for real bindings that need system
# libs absent from CI, fall back to the default feature set.
cargo build --benches --all-features || cargo build --benches

echo "== example targets compile =="
cargo build --examples

echo "== shard/merge round-trip (3 processes vs single process, bit-identical) =="
BIN=target/release/cimdse
SHARD_DIR=$(mktemp -d)
trap 'rm -rf "$SHARD_DIR"' EXIT
SPEC_ARGS=(sweep --spec dense --points 6)
for i in 0 1 2; do
  "$BIN" "${SPEC_ARGS[@]}" --shard "$i/3" --out "$SHARD_DIR/shard_$i.json"
done
"$BIN" merge-shards "$SHARD_DIR"/shard_0.json "$SHARD_DIR"/shard_1.json \
  "$SHARD_DIR"/shard_2.json --out "$SHARD_DIR/merged.json"
"$BIN" "${SPEC_ARGS[@]}" --summary-json "$SHARD_DIR/single.json"
cmp "$SHARD_DIR/merged.json" "$SHARD_DIR/single.json"
echo "merged shards == single-process summary (byte-identical)"

echo "== shard resume (completed artifact skipped, deleted one rebuilt) =="
RESUME_OUT=$("$BIN" "${SPEC_ARGS[@]}" --shard 0/3 --out "$SHARD_DIR/shard_0.json")
echo "$RESUME_OUT" | grep -q "already complete" \
  || { echo "ci.sh: completed shard was not skipped: $RESUME_OUT" >&2; exit 1; }
rm "$SHARD_DIR/shard_1.json"
"$BIN" "${SPEC_ARGS[@]}" --shard 1/3 --out "$SHARD_DIR/shard_1.json"
"$BIN" merge-shards "$SHARD_DIR"/shard_*.json --out "$SHARD_DIR/merged2.json"
cmp "$SHARD_DIR/merged.json" "$SHARD_DIR/merged2.json"
echo "resumed shard set merges identically"

echo "== perf_hotpaths (quick mode) -> BENCH_sweep.json =="
rm -f BENCH_sweep.json
CIMDSE_BENCH_QUICK=1 cargo bench --bench perf_hotpaths

echo "== validate BENCH_sweep.json =="
# Hard gate: a missing or malformed perf artifact fails CI.
test -s BENCH_sweep.json || { echo "ci.sh: BENCH_sweep.json missing or empty" >&2; exit 1; }
cargo run --quiet --release -- bench-report --path BENCH_sweep.json

echo "ci.sh: all green"
